//! Minimal HTTP/1.1 front end (std::net + in-repo thread pool).
//!
//! Endpoints:
//! * `POST /v1/embed` — body `{"texts": ["...", ...]}`; each text goes
//!   through Algorithm 1 admission independently; response carries the
//!   route per text. Full-queue rejection maps to **503** with
//!   `{"error":"busy"}` — the paper's 'busy' status.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — metrics registry snapshot (JSON).
//! * `GET /stats` — queue depths/occupancy + route counters.

pub mod http;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::service::{ServeError, WindVE};
use crate::util::json::{self, Json};
use crate::util::threadpool::ThreadPool;
use http::{Request, Response};

/// Running HTTP server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `listen` and serve `svc` until [`Server::stop`] (or drop).
    pub fn start(listen: &str, svc: Arc<WindVE>, slo: Duration) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("windve-http".into())
            .spawn(move || {
                let pool = ThreadPool::new(16);
                loop {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let svc = Arc::clone(&svc);
                            pool.execute(move || {
                                let _ = handle_connection(stream, &svc, slo);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => {
                            log::warn!("accept error: {e}");
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(Server { addr, stop, join: Some(join) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn handle_connection(mut stream: TcpStream, svc: &WindVE, slo: Duration) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true)?;
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let resp = Response::bad_request(&format!("{e:#}"));
            let _ = stream.write_all(resp.serialize().as_bytes());
            return Ok(());
        }
    };
    let resp = route(&req, svc, slo);
    stream.write_all(resp.serialize().as_bytes())?;
    Ok(())
}

fn route(req: &Request, svc: &WindVE, slo: Duration) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::ok_json(Json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/metrics") => Response::ok_json(svc.metrics.snapshot()),
        ("GET", "/stats") => {
            let qm = svc.queue_manager();
            let stats = qm.stats();
            // Read-side lock recoveries on the attached retrieval index
            // (0 when no index is attached) — the poisoning satellite's
            // operator signal.
            let poisoned = svc.retrieval().map_or(0, |e| e.poisoned_recoveries());
            Response::ok_json(Json::obj(vec![
                ("npu_depth", Json::num(qm.npu_depth() as f64)),
                ("cpu_depth", Json::num(qm.cpu_depth() as f64)),
                ("npu_occupancy", Json::num(qm.npu_occupancy() as f64)),
                ("cpu_occupancy", Json::num(qm.cpu_occupancy() as f64)),
                ("embed_cpu_occupancy", Json::num(qm.embed_cpu_occupancy() as f64)),
                ("retrieve_cpu_occupancy", Json::num(qm.retrieve_cpu_occupancy() as f64)),
                ("retrieve_cap", Json::num(qm.retrieve_cap() as f64)),
                ("embed_npu_occupancy", Json::num(qm.embed_npu_occupancy() as f64)),
                ("retrieve_npu_occupancy", Json::num(qm.retrieve_npu_occupancy() as f64)),
                ("npu_retrieve_cap", Json::num(qm.npu_retrieve_cap() as f64)),
                ("hetero", Json::Bool(qm.hetero())),
                ("routed_npu", Json::num(stats.routed_npu as f64)),
                ("routed_cpu", Json::num(stats.routed_cpu as f64)),
                ("rejected", Json::num(stats.rejected as f64)),
                ("routed_retrieve", Json::num(stats.routed_retrieve as f64)),
                ("rejected_retrieve", Json::num(stats.rejected_retrieve as f64)),
                ("routed_retrieve_npu", Json::num(stats.routed_retrieve_npu as f64)),
                ("rejected_retrieve_npu", Json::num(stats.rejected_retrieve_npu as f64)),
                ("retrieval_poisoned_recoveries", Json::num(poisoned as f64)),
                ("bad_releases", Json::num(stats.bad_releases as f64)),
            ]))
        }
        ("POST", "/v1/embed") => embed_endpoint(req, svc, slo),
        _ => Response::not_found(),
    }
}

fn embed_endpoint(req: &Request, svc: &WindVE, slo: Duration) -> Response {
    let body = match json::parse(&req.body) {
        Ok(b) => b,
        Err(e) => return Response::bad_request(&format!("bad json: {e}")),
    };
    let texts: Vec<String> = if let Some(arr) = body.get("texts").and_then(|t| t.as_arr()) {
        arr.iter()
            .filter_map(|t| t.as_str().map(|s| s.to_string()))
            .collect()
    } else if let Some(t) = body.get("text").and_then(Json::as_str) {
        vec![t.to_string()]
    } else {
        return Response::bad_request("expected {\"texts\": [...]} or {\"text\": \"...\"}");
    };
    if texts.is_empty() {
        return Response::bad_request("no texts");
    }

    // Admit all texts first (each is one Algorithm-1 query), then wait.
    let mut tickets = Vec::with_capacity(texts.len());
    for t in &texts {
        match svc.submit(t.clone()) {
            Ok(ticket) => tickets.push(ticket),
            Err(ServeError::Busy) => {
                // Busy any → reject the whole request with 'busy' status
                // (tickets already admitted still complete and release
                // their slots; their results are dropped).
                for tk in tickets {
                    let _ = tk.wait(slo.mul_f64(4.0));
                }
                return Response::busy();
            }
            Err(e) => return Response::server_error(&e.to_string()),
        }
    }
    let mut embeddings = Vec::with_capacity(tickets.len());
    let mut routes = Vec::with_capacity(tickets.len());
    for tk in tickets {
        routes.push(tk.route.to_string());
        match tk.wait(slo.mul_f64(4.0)) {
            Ok(v) => embeddings.push(Json::Arr(
                v.into_iter().map(|x| Json::Num(x as f64)).collect(),
            )),
            Err(e) => return Response::server_error(&e.to_string()),
        }
    }
    Response::ok_json(Json::obj(vec![
        ("embeddings", Json::Arr(embeddings)),
        (
            "routes",
            Json::Arr(routes.into_iter().map(Json::Str).collect()),
        ),
    ]))
}
