//! HTTP/1.1 front end (std::net + in-repo thread pool), keep-alive and
//! streaming-ingest aware.
//!
//! # Endpoints
//!
//! * `POST /v1/embed` — body `{"texts": ["...", ...]}` (or
//!   `{"text": "..."}`); each text goes through Algorithm 1 admission
//!   independently; the response carries the route per text. Full-queue
//!   rejection maps to **503** `{"error":"busy"}` — the paper's 'busy'
//!   status. Texts are parsed zero-copy and submitted as shared
//!   `Arc<str>` payloads (no per-hop clone).
//! * `POST /v1/corpus` — **streaming NDJSON ingest**: one
//!   `{"id": <u64>, "text": "..."}` document per line, with chunked
//!   `Transfer-Encoding` supported (and encouraged — uploads of any
//!   size parse at one-chunk residency; the body is never materialized).
//!   Documents embed through the strictly-capped `WorkClass::Ingest`
//!   (see `coordinator::queue_manager`: shared-pool accounting + a hard
//!   per-pool cap means bulk uploads can never oversubscribe the
//!   calibrated depth or starve Embed/Retrieve; admission BUSY becomes
//!   socket backpressure) and commit in batches to the live index,
//!   bumping the corpus version so NPU mirrors invalidate. Response:
//!   `{"received", "indexed", "failed", "busy_waits", "batches",
//!   "corpus_version", "peak_chunk_bytes", "error"}`. Requires an
//!   attached retrieval index.
//! * `GET /v1/ingest/status` — service-lifetime ingest counters
//!   (`docs_received/indexed/failed`, `busy_waits`,
//!   `batches_committed`, `streams_completed`, `active_streams`,
//!   `peak_chunk_bytes`, `corpus_version`).
//! * `DELETE /v1/corpus/{id}` — tombstone one document (`{id}` is the
//!   decimal u64 the document was ingested under). The row stops
//!   matching immediately (same version seam as adds, so NPU mirrors
//!   invalidate); with a durable store attached the delete is WAL-logged
//!   before the index mutation. Response: `{"id", "removed",
//!   "corpus_version"}` — `removed: 0` means the id was unknown (still
//!   200; deletes are idempotent).
//! * `POST /v1/corpus/snapshot` — checkpoint the corpus: serialize the
//!   index to a durable snapshot and truncate the WAL behind it.
//!   Response: `{"watermark"}`. Requires an attached durable store.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — metrics registry snapshot (JSON).
//! * `GET /stats` — queue depths/occupancy + route counters for all
//!   three work classes (embed / retrieve / ingest, both device legs);
//!   when a durable store is attached, a nested `"durability"` object
//!   (`committed_seq`, `wal_segments`, `wal_bytes`, `replayed_records`,
//!   `snapshots_written`, `compactions`, `wal_append_failures`).
//!
//! # Connection handling
//!
//! Connections are **keep-alive** (HTTP/1.1 default, `Connection`
//! header respected) up to [`MAX_REQUESTS_PER_CONN`] requests; bytes
//! read past one message stay buffered for the next. A request whose
//! body errors mid-stream closes the connection (the only safe framing
//! recovery).
//!
//! **Slow-loris guard**: the per-read socket timeout only bounds each
//! read — a client trickling one byte per few seconds would hold a pool
//! thread forever. Every request therefore also gets a wall-clock
//! budget ([`DEFAULT_REQUEST_DEADLINE`], tunable via
//! [`Server::start_with_deadline`]), armed when its first byte arrives
//! and spanning head + body; exceeding it answers **408** and closes
//! the connection. Idle keep-alive waits don't count against it.

pub mod http;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::service::{ServeError, WindVE};
use crate::ingest::{self, IngestOptions};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use http::{Conn, Head, Response};

/// Bounded keep-alive: one connection serves at most this many requests
/// before the server closes it (resource rotation under slow clients).
pub const MAX_REQUESTS_PER_CONN: usize = 128;

/// Default per-request wall-clock budget (head + body) — the slow-loris
/// guard. Generous: a legitimate chunked corpus upload streams fast;
/// only a byte-trickling client spends half a minute on one request.
pub const DEFAULT_REQUEST_DEADLINE: Duration = Duration::from_secs(30);

/// Running HTTP server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `listen` and serve `svc` until [`Server::stop`] (or drop),
    /// with the default per-request deadline.
    pub fn start(listen: &str, svc: Arc<WindVE>, slo: Duration) -> Result<Server> {
        Server::start_with_deadline(listen, svc, slo, DEFAULT_REQUEST_DEADLINE)
    }

    /// [`Server::start`] with an explicit per-request wall-clock budget
    /// (the slow-loris guard; see the module docs). Tests use a short
    /// budget to exercise the 408 path without waiting 30s.
    pub fn start_with_deadline(
        listen: &str,
        svc: Arc<WindVE>,
        slo: Duration,
        request_deadline: Duration,
    ) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("windve-http".into())
            .spawn(move || {
                let pool = ThreadPool::new(16);
                loop {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let svc = Arc::clone(&svc);
                            pool.execute(move || {
                                let _ = handle_connection(stream, &svc, slo, request_deadline);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => {
                            log::warn!("accept error: {e}");
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(Server { addr, stop, join: Some(join) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Serve one connection: keep-alive loop with the per-connection
/// request bound. Returns when the peer closes, a framing error forces
/// a close, or the bound is reached.
fn handle_connection(
    stream: TcpStream,
    svc: &WindVE,
    slo: Duration,
    request_deadline: Duration,
) -> Result<()> {
    // Per-read timeout ≤ the request budget, so a stalled read wakes up
    // in time for the wall-clock deadline check in `Conn::fill`.
    stream.set_read_timeout(Some(Duration::from_secs(10).min(request_deadline)))?;
    stream.set_nodelay(true)?;
    let mut conn = Conn::with_budget(stream, request_deadline);
    for served in 0..MAX_REQUESTS_PER_CONN {
        let head = match conn.read_head() {
            Ok(Some(h)) => h,
            Ok(None) => return Ok(()), // clean keep-alive close
            Err(e) => {
                // A request that started but blew its wall-clock budget
                // (slow-loris): 408 and close. An idle keep-alive peer
                // that never sent a byte times out silently. Anything
                // else is a malformed head worth a 400.
                if conn.deadline_exceeded() {
                    let resp = Response::request_timeout();
                    let _ = conn.stream_mut().write_all(resp.serialize_with(false).as_bytes());
                    return Ok(());
                }
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if !timed_out {
                    let resp = Response::bad_request(&format!("{e:#}"));
                    let _ = conn.stream_mut().write_all(resp.serialize_with(false).as_bytes());
                }
                return Ok(());
            }
        };
        let keep = head.wants_keep_alive() && served + 1 < MAX_REQUESTS_PER_CONN;

        // The streaming endpoint drives the body itself — never
        // materialized, so it bypasses the read_body_string path.
        if head.method == "POST" && head.path == "/v1/corpus" {
            let (resp, body_ok) = corpus_endpoint(&mut conn, &head, svc);
            // A deadline trip mid-stream surfaced as an ingest error;
            // report it as the timeout it is.
            let resp =
                if conn.deadline_exceeded() { Response::request_timeout() } else { resp };
            let keep = keep && body_ok && !conn.deadline_exceeded();
            conn.stream_mut().write_all(resp.serialize_with(keep).as_bytes())?;
            if !keep {
                return Ok(());
            }
            conn.finish_request();
            continue;
        }

        let body = match conn.read_body_string(&head) {
            Ok(b) => b,
            Err(e) => {
                // Framing is unknown past an aborted body: must close.
                let resp = if conn.deadline_exceeded() {
                    Response::request_timeout()
                } else {
                    Response::bad_request(&format!("{e:#}"))
                };
                let _ = conn.stream_mut().write_all(resp.serialize_with(false).as_bytes());
                return Ok(());
            }
        };
        let resp = route(&head, &body, svc, slo);
        conn.stream_mut().write_all(resp.serialize_with(keep).as_bytes())?;
        if !keep {
            return Ok(());
        }
        conn.finish_request();
    }
    Ok(())
}

fn route(head: &Head, body: &str, svc: &WindVE, slo: Duration) -> Response {
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/healthz") => Response::ok_json(Json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/metrics") => Response::ok_json(svc.metrics.snapshot()),
        ("GET", "/v1/ingest/status") => {
            let version = svc.retrieval().map(|e| e.version());
            Response::ok_json(svc.ingest_stats().to_json(version))
        }
        ("GET", "/stats") => {
            let qm = svc.queue_manager();
            let stats = qm.stats();
            // Read-side lock recoveries on the attached retrieval index
            // (0 when no index is attached) — the poisoning satellite's
            // operator signal.
            let poisoned = svc.retrieval().map_or(0, |e| e.poisoned_recoveries());
            let mut fields = vec![
                ("npu_depth", Json::num(qm.npu_depth() as f64)),
                ("cpu_depth", Json::num(qm.cpu_depth() as f64)),
                ("npu_occupancy", Json::num(qm.npu_occupancy() as f64)),
                ("cpu_occupancy", Json::num(qm.cpu_occupancy() as f64)),
                ("embed_cpu_occupancy", Json::num(qm.embed_cpu_occupancy() as f64)),
                ("retrieve_cpu_occupancy", Json::num(qm.retrieve_cpu_occupancy() as f64)),
                ("ingest_cpu_occupancy", Json::num(qm.ingest_cpu_occupancy() as f64)),
                ("retrieve_cap", Json::num(qm.retrieve_cap() as f64)),
                ("ingest_cap", Json::num(qm.ingest_cap() as f64)),
                ("embed_npu_occupancy", Json::num(qm.embed_npu_occupancy() as f64)),
                ("retrieve_npu_occupancy", Json::num(qm.retrieve_npu_occupancy() as f64)),
                ("ingest_npu_occupancy", Json::num(qm.ingest_npu_occupancy() as f64)),
                ("npu_retrieve_cap", Json::num(qm.npu_retrieve_cap() as f64)),
                ("npu_ingest_cap", Json::num(qm.npu_ingest_cap() as f64)),
                ("hetero", Json::Bool(qm.hetero())),
                ("routed_npu", Json::num(stats.routed_npu as f64)),
                ("routed_cpu", Json::num(stats.routed_cpu as f64)),
                ("rejected", Json::num(stats.rejected as f64)),
                ("routed_retrieve", Json::num(stats.routed_retrieve as f64)),
                ("rejected_retrieve", Json::num(stats.rejected_retrieve as f64)),
                ("routed_retrieve_npu", Json::num(stats.routed_retrieve_npu as f64)),
                ("rejected_retrieve_npu", Json::num(stats.rejected_retrieve_npu as f64)),
                ("routed_ingest", Json::num(stats.routed_ingest as f64)),
                ("rejected_ingest", Json::num(stats.rejected_ingest as f64)),
                ("routed_ingest_npu", Json::num(stats.routed_ingest_npu as f64)),
                ("rejected_ingest_npu", Json::num(stats.rejected_ingest_npu as f64)),
                ("retrieval_poisoned_recoveries", Json::num(poisoned as f64)),
                ("bad_releases", Json::num(stats.bad_releases as f64)),
            ];
            if let Some(store) = svc.durability() {
                let d = store.stats();
                fields.push((
                    "durability",
                    Json::obj(vec![
                        ("committed_seq", Json::num(d.committed_seq as f64)),
                        ("wal_segments", Json::num(d.wal_segments as f64)),
                        ("wal_bytes", Json::num(d.wal_bytes as f64)),
                        ("replayed_records", Json::num(d.replayed_records as f64)),
                        ("snapshots_written", Json::num(d.snapshots_written as f64)),
                        ("compactions", Json::num(d.compactions as f64)),
                        ("wal_append_failures", Json::num(d.wal_append_failures as f64)),
                    ]),
                ));
            }
            Response::ok_json(Json::obj(fields))
        }
        ("POST", "/v1/embed") => embed_endpoint(body, svc, slo),
        ("POST", "/v1/corpus/snapshot") => match svc.snapshot_corpus() {
            Ok(watermark) => Response::ok_json(Json::obj(vec![(
                "watermark",
                Json::num(watermark as f64),
            )])),
            Err(e) => Response::server_error(&e.to_string()),
        },
        ("DELETE", p) if p.starts_with("/v1/corpus/") => {
            match p["/v1/corpus/".len()..].parse::<u64>() {
                Ok(id) => match svc.delete_doc(id) {
                    Ok(removed) => Response::ok_json(Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("removed", Json::num(removed as f64)),
                        (
                            "corpus_version",
                            svc.retrieval().map_or(Json::Null, |e| Json::num(e.version() as f64)),
                        ),
                    ])),
                    Err(e) => Response::server_error(&e.to_string()),
                },
                Err(_) => Response::bad_request("document id must be a decimal u64"),
            }
        }
        _ => Response::not_found(),
    }
}

/// Streaming corpus ingest. Returns the response plus whether the body
/// was consumed to a clean framing boundary (a mid-body failure means
/// the connection cannot be reused).
fn corpus_endpoint(conn: &mut Conn<TcpStream>, head: &Head, svc: &WindVE) -> (Response, bool) {
    let body = match conn.body(head) {
        Ok(b) => b,
        // Unframeable message: nothing was consumed — 400 and close.
        Err(e) => return (Response::bad_request(&format!("{e:#}")), false),
    };
    let outcome = ingest::ingest_ndjson_chunks(svc, body, &IngestOptions::default());
    match &outcome.error {
        // A stream-level error may have left the body half-read.
        Some(e) => {
            let msg = format!("ingest aborted: {e} ({})", summary(&outcome));
            (Response::bad_request(&msg), false)
        }
        None => (Response::ok_json(outcome.to_json()), true),
    }
}

fn summary(o: &ingest::IngestOutcome) -> String {
    format!("{} received, {} indexed, {} failed", o.received, o.indexed, o.failed)
}

/// `POST /v1/embed`: parse with the zero-copy parser and submit each
/// text by `Arc<str>` — the only copy is input bytes → shared payload
/// (escape-free strings are borrowed straight from the body until that
/// point; no intermediate `String` per text).
fn embed_endpoint(body: &str, svc: &WindVE, slo: Duration) -> Response {
    use crate::ingest::ndjson::{parse_slice, Value};

    let parsed = match parse_slice(body.as_bytes()) {
        Ok(v) => v,
        Err(e) => return Response::bad_request(&format!("bad json: {e}")),
    };
    let texts: Vec<Arc<str>> = match (parsed.get("texts"), parsed.get("text")) {
        (Some(Value::Arr(items)), _) => items
            .iter()
            .filter_map(|t| t.as_str().map(Arc::<str>::from))
            .collect(),
        (None, Some(Value::Str(s))) => vec![Arc::<str>::from(s.as_ref())],
        _ => {
            return Response::bad_request(
                "expected {\"texts\": [...]} or {\"text\": \"...\"}",
            )
        }
    };
    if texts.is_empty() {
        return Response::bad_request("no texts");
    }

    // Admit all texts first (each is one Algorithm-1 query), then wait.
    let mut tickets = Vec::with_capacity(texts.len());
    for t in &texts {
        match svc.submit(Arc::clone(t)) {
            Ok(ticket) => tickets.push(ticket),
            Err(ServeError::Busy) => {
                // Busy any → reject the whole request with 'busy' status
                // (tickets already admitted still complete and release
                // their slots; their results are dropped).
                for tk in tickets {
                    let _ = tk.wait(slo.mul_f64(4.0));
                }
                return Response::busy();
            }
            Err(e) => return Response::server_error(&e.to_string()),
        }
    }
    let mut embeddings = Vec::with_capacity(tickets.len());
    let mut routes = Vec::with_capacity(tickets.len());
    for tk in tickets {
        routes.push(tk.route.to_string());
        match tk.wait(slo.mul_f64(4.0)) {
            Ok(v) => embeddings.push(Json::Arr(
                v.into_iter().map(|x| Json::Num(x as f64)).collect(),
            )),
            Err(e) => return Response::server_error(&e.to_string()),
        }
    }
    Response::ok_json(Json::obj(vec![
        ("embeddings", Json::Arr(embeddings)),
        (
            "routes",
            Json::Arr(routes.into_iter().map(Json::Str).collect()),
        ),
    ]))
}
