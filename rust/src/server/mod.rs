//! HTTP/1.1 front end for the **v1 API** (std::net only — no
//! framework): an event-driven readiness-loop server on unix, a
//! thread-per-connection fallback elsewhere.
//!
//! # Architecture
//!
//! On unix, [`Server::start`] runs the [`reactor`]: one thread owns
//! every connection through an epoll (Linux) or poll(2) readiness
//! loop — non-blocking sockets, the incremental [`http::Conn`] parser
//! driven by readable events, write-side buffering for partially
//! flushed responses, and per-connection deadlines in a hashed timer
//! wheel ([`timer::TimerWheel`]). Handlers run on a small bounded
//! worker pool ([`ServerOptions::handler_workers`]); 10k+ idle
//! keep-alive connections cost file descriptors, not threads. The
//! pre-existing thread-per-connection loop remains as
//! [`Server::start_threaded`] (and the non-unix default): identical
//! observable behavior, one pool thread pinned per live connection.
//!
//! Routing is shared by both modes: the typed [`router::Router`] maps
//! method + path to an endpoint (with `{id}` params parsed exactly
//! once) and [`dispatch_outcome`] turns the outcome into a response —
//! including automatic **405** with an `Allow` header and **400**
//! `invalid_id` for malformed typed params.
//!
//! # Endpoints (see `docs/API.md` for the full contract)
//!
//! * `POST /v1/embed` — body `{"texts": ["...", ...]}` (or
//!   `{"text": "..."}`); each text goes through Algorithm 1 admission
//!   independently; the response carries the route per text.
//!   Full-queue rejection maps to **503** with error code `busy` and a
//!   `Retry-After` header derived from queue occupancy.
//! * `POST /v1/corpus` — **streaming NDJSON ingest**: one
//!   `{"id": <u64>, "text": "..."}` document per line, chunked
//!   `Transfer-Encoding` supported (and encouraged). The body is never
//!   materialized; admission BUSY becomes socket backpressure. In the
//!   readiness loop this endpoint *detaches*: after the head parses the
//!   connection leaves the reactor, a pool worker drives the blocking
//!   ingest pipeline, and the connection re-attaches for keep-alive
//!   afterwards.
//! * `GET /v1/ingest/status` — service-lifetime ingest counters.
//! * `DELETE /v1/corpus/{id}` — tombstone one document; `{id}` is a
//!   typed decimal-u64 route param (anything else is **400**
//!   `invalid_id`). Deletes are idempotent.
//! * `POST /v1/corpus/snapshot` — checkpoint the corpus (durable store
//!   required).
//! * `GET /v1/healthz` — liveness. `GET /v1/metrics` — metrics
//!   registry snapshot. `GET /v1/stats` — queue depths/occupancy +
//!   route counters (+ a `"durability"` object when a store is
//!   attached).
//! * `/healthz`, `/metrics`, `/stats` — **deprecated aliases** of the
//!   `/v1/` paths: same bodies, plus a `Deprecation: true` header.
//!
//! Every error response carries the versioned envelope
//! `{"error":{"code","message"}}` (see [`http::Response::error`]).
//!
//! # Connection handling
//!
//! Connections are **keep-alive** (HTTP/1.1 default, `Connection`
//! header respected) up to [`MAX_REQUESTS_PER_CONN`] requests; bytes
//! read past one message stay buffered for the next. A request whose
//! body errors mid-stream closes the connection (the only safe framing
//! recovery).
//!
//! Two independent clocks govern each connection:
//!
//! * **Request deadline** ([`DEFAULT_REQUEST_DEADLINE`]) — the
//!   slow-loris guard: armed when a request's first byte arrives,
//!   spanning head + body; exceeding it answers **408** and closes.
//! * **Idle timeout** ([`DEFAULT_IDLE_TIMEOUT`]) — how long a
//!   keep-alive connection may sit between requests before the server
//!   silently closes it. Idle waits never count against a request
//!   deadline.

pub mod http;
pub mod router;
pub mod timer;

#[cfg(unix)]
mod reactor;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::service::{ServeError, WindVE};
use crate::ingest::{self, IngestOptions};
use crate::metrics::trace::{ClassLabel, CodecLabel, RouteLabel, Stage};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use http::{Conn, Head, Response};
use router::{Endpoint, RouteMatch, RouteOutcome, Router};

/// Bounded keep-alive: one connection serves at most this many requests
/// before the server closes it (resource rotation under slow clients).
pub const MAX_REQUESTS_PER_CONN: usize = 128;

/// Default per-request wall-clock budget (head + body) — the slow-loris
/// guard. Generous: a legitimate chunked corpus upload streams fast;
/// only a byte-trickling client spends half a minute on one request.
pub const DEFAULT_REQUEST_DEADLINE: Duration = Duration::from_secs(30);

/// Default idle keep-alive timeout: a connection with no request in
/// flight is closed after this long without a byte.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(10);

/// Default handler worker pool size for the readiness-loop server.
/// Handlers are short (admission waits dominate); connection count is
/// decoupled from this entirely.
pub const DEFAULT_HANDLER_WORKERS: usize = 8;

/// Server tuning knobs (see module docs for the two clocks).
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Embed SLO handed to handlers (ticket waits are bounded by a
    /// multiple of it).
    pub slo: Duration,
    /// Per-request wall-clock budget (slow-loris guard).
    pub request_deadline: Duration,
    /// Keep-alive idle timeout.
    pub idle_timeout: Duration,
    /// Readiness-loop handler pool size (ignored by the threaded mode,
    /// which spends a pool thread per connection instead).
    pub handler_workers: usize,
    /// Force the thread-per-connection mode even where the readiness
    /// loop is available (comparison benches, soak baselines).
    pub force_threaded: bool,
}

impl ServerOptions {
    pub fn new(slo: Duration) -> ServerOptions {
        ServerOptions {
            slo,
            request_deadline: DEFAULT_REQUEST_DEADLINE,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            handler_workers: DEFAULT_HANDLER_WORKERS,
            force_threaded: false,
        }
    }
}

/// Running HTTP server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Reactor wake channel: a byte here interrupts the poll wait so
    /// the stop flag is seen immediately (None in threaded mode).
    wake: Option<Arc<TcpStream>>,
}

impl Server {
    /// Bind `listen` and serve `svc` until [`Server::stop`] (or drop),
    /// with default options — the readiness loop on unix.
    pub fn start(listen: &str, svc: Arc<WindVE>, slo: Duration) -> Result<Server> {
        Server::start_with_options(listen, svc, ServerOptions::new(slo))
    }

    /// [`Server::start`] with an explicit per-request wall-clock budget
    /// (the slow-loris guard; see the module docs). Tests use a short
    /// budget to exercise the 408 path without waiting 30s.
    pub fn start_with_deadline(
        listen: &str,
        svc: Arc<WindVE>,
        slo: Duration,
        request_deadline: Duration,
    ) -> Result<Server> {
        let opts = ServerOptions { request_deadline, ..ServerOptions::new(slo) };
        Server::start_with_options(listen, svc, opts)
    }

    /// The thread-per-connection mode, explicitly (soak baselines and
    /// concurrency benches compare against this).
    pub fn start_threaded(listen: &str, svc: Arc<WindVE>, slo: Duration) -> Result<Server> {
        let opts = ServerOptions { force_threaded: true, ..ServerOptions::new(slo) };
        Server::start_with_options(listen, svc, opts)
    }

    /// Bind `listen` and serve with explicit [`ServerOptions`].
    pub fn start_with_options(
        listen: &str,
        svc: Arc<WindVE>,
        opts: ServerOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        #[cfg(unix)]
        if !opts.force_threaded {
            let handle = reactor::spawn(listener, svc, opts, Arc::clone(&stop))?;
            return Ok(Server {
                addr,
                stop,
                join: Some(handle.join),
                wake: Some(handle.wake_tx),
            });
        }

        listener.set_nonblocking(true)?;
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("windve-http".into())
            .spawn(move || {
                let pool = ThreadPool::new(16);
                loop {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let svc = Arc::clone(&svc);
                            pool.execute(move || {
                                let _ = handle_connection(stream, &svc, &opts);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => {
                            log::warn!("accept error: {e}");
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(Server { addr, stop, join: Some(join), wake: None })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(w) = &self.wake {
            // One byte interrupts the reactor's poll wait.
            let mut s: &TcpStream = w;
            let _ = s.write(&[1u8]);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-request context threaded from head parse to response: the trace
/// ID minted at accept (0 = tracing disabled) and the negotiated
/// response representation. Shared by both server modes.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ReqCtx {
    /// Trace ID every span of this request records under.
    pub(crate) trace: u64,
    /// `Accept: text/plain` → Prometheus text on `/v1/metrics`.
    pub(crate) accept_text: bool,
}

impl ReqCtx {
    pub(crate) fn new(svc: &WindVE, head: &Head) -> ReqCtx {
        ReqCtx {
            trace: svc.mint_trace(),
            accept_text: head
                .header("accept")
                .is_some_and(|a| a.contains("text/plain")),
        }
    }
}

/// Record the respond-stage span: serialize + flush of one response,
/// attributed request-wide (no class/route/codec at this layer).
pub(crate) fn record_respond(svc: &WindVE, trace: u64, t0: Instant) {
    if trace == 0 {
        return;
    }
    if let Some(tr) = svc.tracer() {
        tr.span(
            trace,
            Stage::Respond,
            ClassLabel::All,
            RouteLabel::All,
            CodecLabel::All,
            t0,
            t0.elapsed(),
        );
    }
}

/// Serve one connection (threaded mode): keep-alive loop with the
/// per-connection request bound. Returns when the peer closes, a
/// framing error forces a close, the idle timeout lapses, or the bound
/// is reached.
fn handle_connection(stream: TcpStream, svc: &WindVE, opts: &ServerOptions) -> Result<()> {
    // Per-read timeout ≤ the request budget, so a stalled read wakes up
    // in time for the wall-clock deadline check in `Conn::fill` — and
    // in time for the idle-timeout check below.
    stream.set_read_timeout(Some(Duration::from_secs(10).min(opts.request_deadline)))?;
    stream.set_nodelay(true)?;
    let mut conn = Conn::with_budget(stream, opts.request_deadline);
    let mut served = 0;
    let mut idle_since = Instant::now();
    while served < MAX_REQUESTS_PER_CONN {
        let head = match conn.read_head() {
            Ok(Some(h)) => h,
            Ok(None) => return Ok(()), // clean keep-alive close
            Err(e) => {
                // A request that started but blew its wall-clock budget
                // (slow-loris): 408 and close. An idle keep-alive peer
                // whose read merely timed out gets retried until the
                // idle timeout lapses, then a silent close. Anything
                // else is a malformed head worth a 400.
                if conn.deadline_exceeded() {
                    let resp = Response::request_timeout();
                    let _ = conn.stream_mut().write_all(resp.serialize_with(false).as_bytes());
                    return Ok(());
                }
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out && idle_since.elapsed() < opts.idle_timeout {
                    continue; // still within the idle window: keep waiting
                }
                if !timed_out {
                    let resp = Response::bad_request(&format!("{e:#}"));
                    let _ = conn.stream_mut().write_all(resp.serialize_with(false).as_bytes());
                }
                return Ok(());
            }
        };
        served += 1;
        let keep = head.wants_keep_alive() && served < MAX_REQUESTS_PER_CONN;
        let outcome = Router::route(&head.method, &head.path);
        let ctx = ReqCtx::new(svc, &head);

        // The streaming endpoint drives the body itself — never
        // materialized, so it bypasses the read_body_string path.
        if matches!(&outcome, RouteOutcome::Match(m) if m.endpoint == Endpoint::CorpusIngest) {
            let (resp, body_ok) = corpus_endpoint(&mut conn, &head, svc);
            // A deadline trip mid-stream surfaced as an ingest error;
            // report it as the timeout it is.
            let resp =
                if conn.deadline_exceeded() { Response::request_timeout() } else { resp };
            let keep = keep && body_ok && !conn.deadline_exceeded();
            conn.stream_mut().write_all(resp.serialize_with(keep).as_bytes())?;
            if !keep {
                return Ok(());
            }
            conn.finish_request();
            idle_since = Instant::now();
            continue;
        }

        let body = match conn.read_body_string(&head) {
            Ok(b) => b,
            Err(e) => {
                // Framing is unknown past an aborted body: must close.
                let resp = if conn.deadline_exceeded() {
                    Response::request_timeout()
                } else if e.downcast_ref::<http::BodyTooLarge>().is_some() {
                    Response::payload_too_large(&format!("{e:#}"))
                } else {
                    Response::bad_request(&format!("{e:#}"))
                };
                let _ = conn.stream_mut().write_all(resp.serialize_with(false).as_bytes());
                return Ok(());
            }
        };
        let resp = dispatch_outcome(&outcome, &body, svc, opts.slo, &ctx);
        let respond_t0 = Instant::now();
        conn.stream_mut().write_all(resp.serialize_with(keep).as_bytes())?;
        record_respond(svc, ctx.trace, respond_t0);
        if !keep {
            return Ok(());
        }
        conn.finish_request();
        idle_since = Instant::now();
    }
    Ok(())
}

/// Turn a routing outcome + materialized body into a response. Shared
/// by both server modes (the reactor calls this from pool workers).
/// Traced requests carry their ID back as an `X-Trace-Id` header, so a
/// client can correlate its own request with `GET /v1/trace`.
pub(crate) fn dispatch_outcome(
    outcome: &RouteOutcome,
    body: &str,
    svc: &WindVE,
    slo: Duration,
    ctx: &ReqCtx,
) -> Response {
    let resp = match outcome {
        RouteOutcome::Match(m) => {
            let resp = endpoint_response(m, body, svc, slo, ctx);
            if m.deprecated {
                resp.with_header("Deprecation", "true")
            } else {
                resp
            }
        }
        RouteOutcome::BadParam { message } => Response::invalid_id(message),
        RouteOutcome::MethodNotAllowed { allow } => Response::method_not_allowed(allow),
        RouteOutcome::NotFound => Response::not_found(),
    };
    if ctx.trace != 0 {
        resp.with_header("X-Trace-Id", ctx.trace.to_string())
    } else {
        resp
    }
}

fn endpoint_response(
    m: &RouteMatch,
    body: &str,
    svc: &WindVE,
    slo: Duration,
    ctx: &ReqCtx,
) -> Response {
    match m.endpoint {
        Endpoint::Healthz => Response::ok_json(Json::obj(vec![("ok", Json::Bool(true))])),
        // Content negotiation: `Accept: text/plain` serves the
        // Prometheus text exposition; the default stays the JSON
        // snapshot (the historic contract).
        Endpoint::Metrics if ctx.accept_text => {
            Response::ok_text("text/plain; version=0.0.4", svc.metrics.prometheus())
        }
        Endpoint::Metrics => Response::ok_json(svc.metrics.snapshot()),
        Endpoint::IngestStatus => {
            let version = svc.retrieval().map(|e| e.version());
            Response::ok_json(svc.ingest_stats().to_json(version))
        }
        Endpoint::Stats => stats_response(svc),
        Endpoint::Trace => trace_endpoint(svc),
        Endpoint::Embed => embed_endpoint(body, svc, slo, ctx.trace),
        Endpoint::Search => search_endpoint(body, svc, slo, ctx.trace),
        Endpoint::CorpusSnapshot => match svc.snapshot_corpus() {
            Ok(watermark) => Response::ok_json(Json::obj(vec![(
                "watermark",
                Json::num(watermark as f64),
            )])),
            Err(e) => Response::server_error(&e.to_string()),
        },
        Endpoint::CorpusDelete => {
            let Some(id) = m.id else {
                return Response::server_error("route param missing");
            };
            match svc.delete_doc(id) {
                Ok(removed) => Response::ok_json(Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("removed", Json::num(removed as f64)),
                    (
                        "corpus_version",
                        svc.retrieval().map_or(Json::Null, |e| Json::num(e.version() as f64)),
                    ),
                ])),
                Err(e) => Response::server_error(&e.to_string()),
            }
        }
        // Streaming ingest never reaches the buffered dispatcher: both
        // server modes special-case it off the route outcome.
        Endpoint::CorpusIngest => {
            Response::server_error("streaming endpoint dispatched as buffered")
        }
    }
}

fn stats_response(svc: &WindVE) -> Response {
    let qm = svc.queue_manager();
    let stats = qm.stats();
    // Read-side lock recoveries on the attached retrieval index
    // (0 when no index is attached) — the poisoning satellite's
    // operator signal.
    let poisoned = svc.retrieval().map_or(0, |e| e.poisoned_recoveries());
    let mut fields = vec![
        ("npu_depth", Json::num(qm.npu_depth() as f64)),
        ("cpu_depth", Json::num(qm.cpu_depth() as f64)),
        ("npu_occupancy", Json::num(qm.npu_occupancy() as f64)),
        ("cpu_occupancy", Json::num(qm.cpu_occupancy() as f64)),
        ("embed_cpu_occupancy", Json::num(qm.embed_cpu_occupancy() as f64)),
        ("retrieve_cpu_occupancy", Json::num(qm.retrieve_cpu_occupancy() as f64)),
        ("ingest_cpu_occupancy", Json::num(qm.ingest_cpu_occupancy() as f64)),
        ("retrieve_cap", Json::num(qm.retrieve_cap() as f64)),
        ("ingest_cap", Json::num(qm.ingest_cap() as f64)),
        ("embed_npu_occupancy", Json::num(qm.embed_npu_occupancy() as f64)),
        ("retrieve_npu_occupancy", Json::num(qm.retrieve_npu_occupancy() as f64)),
        ("ingest_npu_occupancy", Json::num(qm.ingest_npu_occupancy() as f64)),
        ("npu_retrieve_cap", Json::num(qm.npu_retrieve_cap() as f64)),
        ("npu_ingest_cap", Json::num(qm.npu_ingest_cap() as f64)),
        ("hetero", Json::Bool(qm.hetero())),
        ("routed_npu", Json::num(stats.routed_npu as f64)),
        ("routed_cpu", Json::num(stats.routed_cpu as f64)),
        ("rejected", Json::num(stats.rejected as f64)),
        ("routed_retrieve", Json::num(stats.routed_retrieve as f64)),
        ("rejected_retrieve", Json::num(stats.rejected_retrieve as f64)),
        ("routed_retrieve_npu", Json::num(stats.routed_retrieve_npu as f64)),
        ("rejected_retrieve_npu", Json::num(stats.rejected_retrieve_npu as f64)),
        ("routed_ingest", Json::num(stats.routed_ingest as f64)),
        ("rejected_ingest", Json::num(stats.rejected_ingest as f64)),
        ("routed_ingest_npu", Json::num(stats.routed_ingest_npu as f64)),
        ("rejected_ingest_npu", Json::num(stats.rejected_ingest_npu as f64)),
        ("retrieval_poisoned_recoveries", Json::num(poisoned as f64)),
        ("bad_releases", Json::num(stats.bad_releases as f64)),
    ];
    if let Some(c) = svc.cache_stats() {
        fields.push((
            "cache",
            Json::obj(vec![
                ("cache_hits", Json::num(c.hits as f64)),
                ("cache_misses", Json::num(c.misses as f64)),
                ("cache_hit_rate", Json::num(c.hit_rate)),
                ("cache_evictions", Json::num(c.evictions as f64)),
                ("cache_entries", Json::num(c.entries as f64)),
                ("cache_capacity", Json::num(c.capacity as f64)),
            ]),
        ));
    }
    if let Some(store) = svc.durability() {
        let d = store.stats();
        fields.push((
            "durability",
            Json::obj(vec![
                ("committed_seq", Json::num(d.committed_seq as f64)),
                ("wal_segments", Json::num(d.wal_segments as f64)),
                ("wal_bytes", Json::num(d.wal_bytes as f64)),
                ("replayed_records", Json::num(d.replayed_records as f64)),
                ("snapshots_written", Json::num(d.snapshots_written as f64)),
                ("compactions", Json::num(d.compactions as f64)),
                ("wal_append_failures", Json::num(d.wal_append_failures as f64)),
            ]),
        ));
    }
    if let Some(g) = svc.slo_governor() {
        fields.push((
            "slo",
            Json::obj(vec![
                ("slo_ms", Json::num(g.slo_nanos() as f64 / 1e6)),
                ("attainment", Json::num(g.attainment())),
                ("breached", Json::Bool(g.breached())),
                ("samples", Json::num(g.samples() as f64)),
                (
                    "recommended_npu_depth",
                    g.recommended_depth().map_or(Json::Null, |d| Json::num(d as f64)),
                ),
                ("retunes", Json::num(g.retunes() as f64)),
            ]),
        ));
    }
    // Per-stage latency quantiles, one object per populated labeled
    // series (`trace.<stage>.<class>.<route>.<codec>`).
    if svc.tracer().is_some() {
        let stages: Vec<(String, Json)> = svc
            .metrics
            .histograms()
            .into_iter()
            .filter(|(name, h)| name.starts_with("trace.") && h.count() > 0)
            .map(|(name, h)| {
                (
                    name,
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("p50_ns", Json::num(h.quantile(0.50) as f64)),
                        ("p95_ns", Json::num(h.p95() as f64)),
                        ("p99_ns", Json::num(h.p99() as f64)),
                    ]),
                )
            })
            .collect();
        fields.push(("stages", Json::Obj(stages)));
    }
    Response::ok_json(Json::obj(fields))
}

/// `GET /v1/trace`: the recent-span ring plus the slow-query log, newest
/// data first-class for a human chasing one request by `X-Trace-Id`.
fn trace_endpoint(svc: &WindVE) -> Response {
    let Some(tr) = svc.tracer() else {
        return Response::ok_json(Json::obj(vec![
            ("enabled", Json::Bool(false)),
            ("spans", Json::Arr(Vec::new())),
            ("slow", Json::Arr(Vec::new())),
        ]));
    };
    let span_json = |s: &crate::metrics::SpanRecord| {
        Json::obj(vec![
            ("trace_id", Json::num(s.trace_id as f64)),
            ("stage", Json::str(s.stage.as_str())),
            ("class", Json::str(s.class.as_str())),
            ("route", Json::str(s.route.as_str())),
            ("codec", Json::str(s.codec.as_str())),
            ("start_ns", Json::num(s.start_ns as f64)),
            ("dur_ns", Json::num(s.dur_ns as f64)),
        ])
    };
    let spans: Vec<Json> = tr.snapshot().iter().map(span_json).collect();
    let slow: Vec<Json> = tr.slow_snapshot().iter().map(span_json).collect();
    Response::ok_json(Json::obj(vec![
        ("enabled", Json::Bool(true)),
        ("capacity", Json::num(tr.capacity() as f64)),
        ("recorded", Json::num(tr.recorded() as f64)),
        ("dropped", Json::num(tr.dropped() as f64)),
        ("slow_threshold_ns", Json::num(tr.slow_threshold_ns() as f64)),
        ("spans", Json::Arr(spans)),
        ("slow", Json::Arr(slow)),
    ]))
}

/// `Retry-After` seconds for a 503: scale with combined queue occupancy
/// — an almost-drained queue suggests retrying in ~1 s, a saturated one
/// backs clients off harder.
fn retry_after_secs(svc: &WindVE) -> u64 {
    let qm = svc.queue_manager();
    let depth = qm.npu_depth() + qm.cpu_depth();
    if depth == 0 {
        return 1;
    }
    let occ = qm.npu_occupancy() + qm.cpu_occupancy();
    (1 + 4 * occ / depth).clamp(1, 8) as u64
}

/// Streaming corpus ingest. Returns the response plus whether the body
/// was consumed to a clean framing boundary (a mid-body failure means
/// the connection cannot be reused).
pub(crate) fn corpus_endpoint(
    conn: &mut Conn<TcpStream>,
    head: &Head,
    svc: &WindVE,
) -> (Response, bool) {
    let body = match conn.body(head) {
        Ok(b) => b,
        // Unframeable message: nothing was consumed — 400 and close.
        Err(e) => return (Response::bad_request(&format!("{e:#}")), false),
    };
    let outcome = ingest::ingest_ndjson_chunks(svc, body, &IngestOptions::default());
    match &outcome.error {
        // A stream-level error may have left the body half-read.
        Some(e) => {
            let msg = format!("ingest aborted: {e} ({})", summary(&outcome));
            (Response::bad_request(&msg), false)
        }
        None => (Response::ok_json(outcome.to_json()), true),
    }
}

fn summary(o: &ingest::IngestOutcome) -> String {
    format!("{} received, {} indexed, {} failed", o.received, o.indexed, o.failed)
}

/// `POST /v1/embed`: parse with the zero-copy parser and submit each
/// text by `Arc<str>` — the only copy is input bytes → shared payload
/// (escape-free strings are borrowed straight from the body until that
/// point; no intermediate `String` per text).
fn embed_endpoint(body: &str, svc: &WindVE, slo: Duration, trace: u64) -> Response {
    use crate::ingest::ndjson::{parse_slice, Value};

    let parsed = match parse_slice(body.as_bytes()) {
        Ok(v) => v,
        Err(e) => return Response::bad_request(&format!("bad json: {e}")),
    };
    let texts: Vec<Arc<str>> = match (parsed.get("texts"), parsed.get("text")) {
        (Some(Value::Arr(items)), _) => items
            .iter()
            .filter_map(|t| t.as_str().map(Arc::<str>::from))
            .collect(),
        (None, Some(Value::Str(s))) => vec![Arc::<str>::from(s.as_ref())],
        _ => {
            return Response::bad_request(
                "expected {\"texts\": [...]} or {\"text\": \"...\"}",
            )
        }
    };
    if texts.is_empty() {
        return Response::bad_request("no texts");
    }

    // Admit all texts first (each is one Algorithm-1 query), then wait.
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(texts.len());
    for t in &texts {
        match svc.submit_traced(Arc::clone(t), trace) {
            Ok(ticket) => tickets.push(ticket),
            Err(ServeError::Busy) => {
                // Busy any → reject the whole request with 'busy' status
                // (tickets already admitted still complete and release
                // their slots; their results are dropped).
                for tk in tickets {
                    let _ = tk.wait(slo.mul_f64(4.0));
                }
                return Response::busy()
                    .with_header("Retry-After", retry_after_secs(svc).to_string());
            }
            Err(e) => return Response::server_error(&e.to_string()),
        }
    }
    let mut embeddings = Vec::with_capacity(tickets.len());
    let mut routes = Vec::with_capacity(tickets.len());
    for tk in tickets {
        routes.push(tk.route.to_string());
        let route = tk.route;
        match tk.wait(slo.mul_f64(4.0)) {
            Ok(v) => {
                // Feed the live SLO governor the served e2e (admission
                // through reply) — this is the latency the SLO is about.
                svc.observe_slo(route, t0.elapsed());
                embeddings.push(Json::Arr(
                    v.into_iter().map(|x| Json::Num(x as f64)).collect(),
                ));
            }
            Err(e) => return Response::server_error(&e.to_string()),
        }
    }
    Response::ok_json(Json::obj(vec![
        ("embeddings", Json::Arr(embeddings)),
        (
            "routes",
            Json::Arr(routes.into_iter().map(Json::Str).collect()),
        ),
    ]))
}

/// `POST /v1/search`: embed the query panel and answer it with one
/// batched top-k scan (the paper's RAG retrieval path). Carries the
/// request trace so the span tree covers embed → scan → merge.
fn search_endpoint(body: &str, svc: &WindVE, slo: Duration, trace: u64) -> Response {
    use crate::ingest::ndjson::{parse_slice, Value};

    let parsed = match parse_slice(body.as_bytes()) {
        Ok(v) => v,
        Err(e) => return Response::bad_request(&format!("bad json: {e}")),
    };
    let queries: Vec<String> = match (parsed.get("queries"), parsed.get("query")) {
        (Some(Value::Arr(items)), _) => items
            .iter()
            .filter_map(|q| q.as_str().map(|s| s.to_string()))
            .collect(),
        (None, Some(Value::Str(s))) => vec![s.to_string()],
        _ => {
            return Response::bad_request(
                "expected {\"queries\": [...]} or {\"query\": \"...\"}",
            )
        }
    };
    if queries.is_empty() {
        return Response::bad_request("no queries");
    }
    let k = parsed
        .get("k")
        .and_then(|v| v.as_f64())
        .map(|f| f as usize)
        .unwrap_or(10)
        .max(1);

    let results = svc.retrieve_blocking_traced(&queries, k, slo.mul_f64(4.0), trace);
    // All-BUSY means admission rejected the whole panel — same 503 +
    // Retry-After contract as /v1/embed. A partial panel still answers.
    if results.iter().all(|r| matches!(r, Err(ServeError::Busy))) {
        return Response::busy()
            .with_header("Retry-After", retry_after_secs(svc).to_string());
    }
    let per_query: Vec<Json> = results
        .into_iter()
        .map(|r| match r {
            Ok(hits) => Json::obj(vec![(
                "hits",
                Json::Arr(
                    hits.into_iter()
                        .map(|h| {
                            Json::obj(vec![
                                ("id", Json::num(h.id as f64)),
                                ("score", Json::num(h.score as f64)),
                            ])
                        })
                        .collect(),
                ),
            )]),
            Err(e) => Json::obj(vec![("error", Json::str(e.to_string()))]),
        })
        .collect();
    Response::ok_json(Json::obj(vec![
        ("k", Json::num(k as f64)),
        ("results", Json::Arr(per_query)),
    ]))
}
