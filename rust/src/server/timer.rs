//! Hashed timer wheel for the reactor's per-connection deadlines.
//!
//! 256 slots × 25 ms tick ≈ a 6.4 s horizon; deadlines beyond it are
//! clamped to the farthest slot and re-hashed when that slot drains
//! (lazy cascade), so arbitrarily long idle timeouts cost nothing extra.
//!
//! **Cancellation is lazy and generation-based**: entries are never
//! removed. The owner bumps its connection's generation counter to
//! cancel; a drained entry whose `(token, gen)` no longer matches the
//! live connection state is simply ignored. A connection serving many
//! requests leaves a trail of stale entries that expire within one
//! deadline period — bounded, and far cheaper than tombstone removal
//! from the middle of a slot.

use std::time::{Duration, Instant};

/// Default tick width — deadline resolution.
pub const TICK: Duration = Duration::from_millis(25);
const SLOTS: usize = 256;

/// A fired deadline: the reactor checks `(token, gen)` against the live
/// connection before acting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fired {
    pub token: u64,
    pub gen: u64,
}

struct Entry {
    at: Instant,
    token: u64,
    gen: u64,
}

pub struct TimerWheel {
    slots: Vec<Vec<Entry>>,
    tick: Duration,
    /// The instant the cursor slot's window starts at.
    cursor_time: Instant,
    cursor: usize,
    len: usize,
}

impl TimerWheel {
    pub fn new(now: Instant) -> TimerWheel {
        TimerWheel::with_tick(now, TICK)
    }

    pub fn with_tick(now: Instant, tick: Duration) -> TimerWheel {
        assert!(tick > Duration::ZERO);
        TimerWheel {
            slots: (0..SLOTS).map(|_| Vec::new()).collect(),
            tick,
            cursor_time: now,
            cursor: 0,
            len: 0,
        }
    }

    /// Schedule `(token, gen)` to fire at `at` (clamped into the wheel's
    /// horizon; beyond-horizon entries re-hash as the wheel turns).
    pub fn insert(&mut self, at: Instant, token: u64, gen: u64) {
        let ticks = if at > self.cursor_time {
            let dt = at.duration_since(self.cursor_time);
            ((dt.as_nanos() / self.tick.as_nanos()) as usize).min(SLOTS - 1)
        } else {
            0
        };
        let slot = (self.cursor + ticks) % SLOTS;
        self.slots[slot].push(Entry { at, token, gen });
        self.len += 1;
    }

    /// Advance the wheel to `now` and return every entry whose deadline
    /// has passed. Entries in drained slots that aren't due yet (they
    /// were clamped from beyond the horizon) are re-hashed.
    pub fn expire(&mut self, now: Instant) -> Vec<Fired> {
        let mut fired = Vec::new();
        while self.cursor_time + self.tick <= now {
            let entries = std::mem::take(&mut self.slots[self.cursor]);
            self.cursor = (self.cursor + 1) % SLOTS;
            self.cursor_time += self.tick;
            for e in entries {
                self.len -= 1;
                if e.at <= now {
                    fired.push(Fired { token: e.token, gen: e.gen });
                } else {
                    self.insert(e.at, e.token, e.gen);
                }
            }
        }
        // Entries in the un-advanced cursor slot can also be due (the
        // slot's window is one tick wide).
        let slot = &mut self.slots[self.cursor];
        let mut i = 0;
        while i < slot.len() {
            if slot[i].at <= now {
                let e = slot.swap_remove(i);
                self.len -= 1;
                fired.push(Fired { token: e.token, gen: e.gen });
            } else {
                i += 1;
            }
        }
        fired
    }

    /// Earliest scheduled deadline — the poll timeout bound. Slots are
    /// ordered by time from the cursor (insert is monotone in `at`), so
    /// the first non-empty slot holds the soonest entry.
    pub fn next_deadline(&self) -> Option<Instant> {
        if self.len == 0 {
            return None;
        }
        for off in 0..SLOTS {
            let s = &self.slots[(self.cursor + off) % SLOTS];
            if let Some(min) = s.iter().map(|e| e.at).min() {
                return Some(min);
            }
        }
        None
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_at_or_after_the_deadline_never_before() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        w.insert(t0 + Duration::from_millis(40), 7, 1);
        assert!(w.expire(t0).is_empty());
        assert!(w.expire(t0 + Duration::from_millis(39)).is_empty());
        let fired = w.expire(t0 + Duration::from_millis(41));
        assert_eq!(fired, vec![Fired { token: 7, gen: 1 }]);
        assert!(w.is_empty());
        assert!(w.expire(t0 + Duration::from_secs(1)).is_empty(), "fires once");
    }

    #[test]
    fn sub_tick_deadlines_fire_from_the_cursor_slot() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        w.insert(t0 + Duration::from_millis(1), 1, 1);
        // The wheel hasn't turned a full tick, yet the entry is due.
        let fired = w.expire(t0 + Duration::from_millis(2));
        assert_eq!(fired.len(), 1);
    }

    #[test]
    fn beyond_horizon_deadlines_cascade() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        // 30 s is far past the 256×25 ms horizon.
        let at = t0 + Duration::from_secs(30);
        w.insert(at, 9, 2);
        // Sweeping up to 29 s re-hashes but never fires.
        for s in [7u64, 14, 21, 29] {
            assert!(w.expire(t0 + Duration::from_secs(s)).is_empty(), "{s}s");
            assert_eq!(w.len(), 1);
        }
        let fired = w.expire(t0 + Duration::from_secs(31));
        assert_eq!(fired, vec![Fired { token: 9, gen: 2 }]);
    }

    #[test]
    fn next_deadline_tracks_the_soonest_entry() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        assert!(w.next_deadline().is_none());
        let late = t0 + Duration::from_millis(900);
        let soon = t0 + Duration::from_millis(60);
        w.insert(late, 1, 1);
        w.insert(soon, 2, 1);
        assert_eq!(w.next_deadline(), Some(soon));
        let fired = w.expire(t0 + Duration::from_millis(61));
        assert_eq!(fired, vec![Fired { token: 2, gen: 1 }]);
        assert_eq!(w.next_deadline(), Some(late));
    }

    #[test]
    fn stale_generations_are_the_cancellation_mechanism() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        w.insert(t0 + Duration::from_millis(30), 5, 1); // armed…
        w.insert(t0 + Duration::from_millis(80), 5, 2); // …then re-armed
        let fired = w.expire(t0 + Duration::from_millis(100));
        // Both entries drain; the owner ignores gen 1 (stale) and acts
        // on gen 2. The wheel itself just reports both.
        assert_eq!(fired.len(), 2);
        assert!(fired.contains(&Fired { token: 5, gen: 2 }));
    }
}
