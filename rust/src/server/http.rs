//! HTTP/1.1 connection handling: incremental head parsing, keep-alive,
//! and streaming bodies (Content-Length and chunked Transfer-Encoding) —
//! still no framework.
//!
//! [`Conn`] owns the per-connection read buffer. Three properties the
//! serving path relies on:
//!
//! * **Linear head scan** — the `\r\n\r\n` search never rescans bytes it
//!   has already rejected: only the last 3 bytes of previously scanned
//!   data plus the new read are examined, so a slow-trickling client
//!   costs O(head) total instead of O(head²).
//! * **Keep-alive correctness** — bytes read past one message (the next
//!   pipelined request) stay in the connection buffer instead of being
//!   truncated, and responses advertise `keep-alive` when the client
//!   asked for it (bounded by the server's per-connection request
//!   limit).
//! * **Streaming bodies** — [`Conn::body`] yields the body as a sequence
//!   of byte chunks without materializing it; `POST /v1/corpus` feeds
//!   them straight into the ingest parser. [`Conn::read_body_string`]
//!   collects them for the small-bodied endpoints, bounded by
//!   [`MAX_BODY`].

use std::collections::HashMap;
use std::io::Read;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

/// A parsed HTTP request with a materialized body (the small-endpoint
/// shape; streaming endpoints work from [`Head`] + [`Conn::body`]).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: String,
}

/// Maximum materialized request size (embedding batches are small;
/// corpus uploads stream and are not subject to this).
pub const MAX_BODY: usize = 4 * 1024 * 1024;
const MAX_HEAD: usize = 64 * 1024;
/// Socket read granularity — also the unit the streaming body hands out,
/// so one ingest "chunk" is at most this many bytes.
const READ_CHUNK: usize = 16 * 1024;

/// Request line + headers (no body yet).
#[derive(Debug, Clone)]
pub struct Head {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    /// True for HTTP/1.1 (keep-alive by default) and anything newer.
    pub http11: bool,
}

impl Head {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    /// Parsed Content-Length. **Errors** (rather than defaulting to
    /// "no body") when the header is present but unparsable — treating
    /// `Content-Length: 99999999999999999999999` or `5, 5` as an empty
    /// body would leave the real body bytes in the connection buffer to
    /// be reparsed as the next keep-alive request (request smuggling);
    /// RFC 9112 requires rejecting the message instead.
    pub fn content_length(&self) -> Result<Option<usize>> {
        match self.headers.get("content-length") {
            None => Ok(None),
            Some(v) => match v.trim().parse::<usize>() {
                Ok(n) => Ok(Some(n)),
                Err(_) => bail!("unparsable Content-Length {v:?}"),
            },
        }
    }

    /// `Transfer-Encoding: chunked` (takes precedence over
    /// Content-Length per RFC 9112 §6.3).
    pub fn chunked(&self) -> bool {
        self.headers
            .get("transfer-encoding")
            .map(|v| v.to_ascii_lowercase().contains("chunked"))
            .unwrap_or(false)
    }

    /// Whether the client wants the connection kept open after this
    /// exchange: explicit `Connection` header first, else the HTTP
    /// version default (1.1 keeps, 1.0 closes).
    pub fn wants_keep_alive(&self) -> bool {
        match self.headers.get("connection").map(|v| v.to_ascii_lowercase()) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// One connection's buffered reader: requests are parsed off the front,
/// and anything read past the current message waits for the next one.
pub struct Conn<S> {
    stream: S,
    buf: Vec<u8>,
    /// Prefix of `buf` already known not to contain the head terminator
    /// (minus a 3-byte overlap) — the incremental-scan cursor.
    scanned: usize,
    /// Per-request wall-clock budget (the slow-loris guard): a socket
    /// read timeout bounds each *read*, so a client trickling one byte
    /// per few seconds holds a connection — and its pool thread —
    /// forever. The budget bounds the whole request instead.
    budget: Option<Duration>,
    /// Armed when the first byte of the current request arrives, cleared
    /// by [`Conn::finish_request`]. Idle keep-alive waits (no bytes yet)
    /// never count against the budget.
    deadline: Option<Instant>,
}

impl<S: Read> Conn<S> {
    pub fn new(stream: S) -> Conn<S> {
        Conn { stream, buf: Vec::with_capacity(1024), scanned: 0, budget: None, deadline: None }
    }

    /// A connection with a per-request wall-clock budget: once any byte
    /// of a request has arrived, head + body must complete within
    /// `budget` or reads fail with `TimedOut` (the caller answers 408
    /// via [`Conn::deadline_exceeded`] and closes).
    pub fn with_budget(stream: S, budget: Duration) -> Conn<S> {
        Conn { budget: Some(budget), ..Conn::new(stream) }
    }

    /// The underlying stream (for writing responses).
    pub fn stream_mut(&mut self) -> &mut S {
        &mut self.stream
    }

    /// The current request is over (response written): stop its clock.
    /// The next request arms a fresh deadline when its first byte lands.
    pub fn finish_request(&mut self) {
        self.deadline = None;
    }

    /// Whether the armed per-request deadline has passed — the signal to
    /// answer 408 instead of 400 on a read failure.
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    fn check_deadline(&self) -> std::io::Result<()> {
        if self.deadline_exceeded() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request wall-clock deadline exceeded",
            ));
        }
        Ok(())
    }

    /// Pull more bytes from the socket into the buffer. Ok(0) = EOF.
    /// While a request is in flight (deadline armed), per-read timeouts
    /// are retried until the wall-clock deadline trips — the guard
    /// tolerates a slow peer but bounds the total.
    fn fill(&mut self) -> std::io::Result<usize> {
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            self.check_deadline()?;
            match self.stream.read(&mut chunk) {
                Ok(n) => {
                    if n > 0 && self.deadline.is_none() {
                        self.deadline = self.budget.map(|b| Instant::now() + b);
                    }
                    self.buf.extend_from_slice(&chunk[..n]);
                    return Ok(n);
                }
                Err(e)
                    if self.deadline.is_some()
                        && matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                {
                    // Mid-request read timeout: loop back, which either
                    // trips the deadline or waits for the next bytes.
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Read the next request head. `Ok(None)` on a clean EOF before any
    /// byte of a new request (the peer closed an idle keep-alive
    /// connection).
    pub fn read_head(&mut self) -> Result<Option<Head>> {
        // A pipelined request already sitting in the buffer starts its
        // clock now — its first byte "arrived" before we looked.
        if !self.buf.is_empty() && self.deadline.is_none() {
            self.deadline = self.budget.map(|b| Instant::now() + b);
        }
        loop {
            if let Some(head) = self.try_parse_head()? {
                return Ok(Some(head));
            }
            if self.fill()? == 0 {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-request");
            }
        }
    }

    /// Pure-buffer head parse: consume and return a complete head if one
    /// is buffered, `Ok(None)` if more bytes are needed. Never touches
    /// the socket — the reactor drives this from readiness events; the
    /// blocking [`Conn::read_head`] wraps it with `fill`.
    pub(crate) fn try_parse_head(&mut self) -> Result<Option<Head>> {
        // Scan only the unscanned tail (plus a 3-byte overlap for a
        // terminator split across reads) — the O(n²) fix.
        let head_end = match find_head_end_from(&self.buf, self.scanned) {
            Some(pos) => pos,
            None => {
                self.scanned = self.buf.len().saturating_sub(3);
                if self.buf.len() > MAX_HEAD {
                    bail!("headers too large");
                }
                return Ok(None);
            }
        };

        let head_str = std::str::from_utf8(&self.buf[..head_end])?;
        let mut lines = head_str.split("\r\n");
        let request_line = lines.next().unwrap_or_default();
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or_default().to_string();
        let path = parts.next().unwrap_or_default().to_string();
        let version = parts.next().unwrap_or("HTTP/1.1");
        if method.is_empty() || path.is_empty() {
            bail!("malformed request line: {request_line:?}");
        }
        let http11 = version != "HTTP/1.0";
        let mut headers = HashMap::new();
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        // Consume the head; pipelined bytes stay buffered for the body
        // reader (or the next request).
        self.buf.drain(..head_end + 4);
        self.scanned = 0;
        Ok(Some(Head { method, path, headers, http11 }))
    }

    /// Streaming reader for `head`'s body. Must be driven to completion
    /// (`next_chunk` until `Ok(None)`) before the connection can carry
    /// another request. **Errors** on unframeable messages (unparsable
    /// Content-Length, a Transfer-Encoding other than chunked): the
    /// caller must respond 400 and close — guessing a framing would
    /// desynchronize the keep-alive stream.
    pub fn body<'c>(&'c mut self, head: &Head) -> Result<BodyReader<'c, S>> {
        let framing = Framing::for_head(head)?;
        Ok(BodyReader { conn: self, framing })
    }

    /// Materialize `head`'s body as a UTF-8 string, bounded by
    /// [`MAX_BODY`] (overflow surfaces as a downcastable
    /// [`BodyTooLarge`], so the front end can answer 413 instead of a
    /// generic 400).
    pub fn read_body_string(&mut self, head: &Head) -> Result<String> {
        if let Some(n) = head.content_length()? {
            if !head.chunked() && n > MAX_BODY {
                return Err(anyhow::Error::new(BodyTooLarge(n)));
            }
        }
        let mut out: Vec<u8> = Vec::new();
        let mut body = self.body(head)?;
        while let Some(chunk) = body.next_chunk()? {
            out.extend_from_slice(&chunk);
            if out.len() > MAX_BODY {
                return Err(anyhow::Error::new(BodyTooLarge(out.len())));
            }
        }
        Ok(String::from_utf8(out)?)
    }

    /// Advance a body framing one step using only buffered bytes. The
    /// single state machine both server modes decode bodies with: the
    /// blocking [`BodyReader`] fills between steps; the reactor steps on
    /// readable events.
    pub(crate) fn decode_step(&mut self, framing: &mut Framing) -> Result<BodyStep> {
        loop {
            match *framing {
                Framing::Done => return Ok(BodyStep::Done),
                Framing::Length { remaining } => {
                    if self.buf.is_empty() {
                        return Ok(BodyStep::NeedMore);
                    }
                    let piece = self.take_buffered(remaining);
                    let left = remaining - piece.len();
                    *framing = if left == 0 {
                        Framing::Done
                    } else {
                        Framing::Length { remaining: left }
                    };
                    return Ok(BodyStep::Chunk(piece));
                }
                Framing::ChunkSize => match self.try_crlf_line()? {
                    None => return Ok(BodyStep::NeedMore),
                    Some(line) => {
                        // Strip chunk extensions ("SIZE;ext=val").
                        let size_str = line.split(';').next().unwrap_or("").trim();
                        let size = usize::from_str_radix(size_str, 16)
                            .map_err(|_| anyhow!("bad chunk size {size_str:?}"))?;
                        *framing = if size == 0 {
                            Framing::Trailer
                        } else {
                            Framing::ChunkData { remaining: size }
                        };
                    }
                },
                Framing::ChunkData { remaining } => {
                    if self.buf.is_empty() {
                        return Ok(BodyStep::NeedMore);
                    }
                    let piece = self.take_buffered(remaining);
                    let left = remaining - piece.len();
                    *framing = if left == 0 {
                        Framing::ChunkCrlf
                    } else {
                        Framing::ChunkData { remaining: left }
                    };
                    return Ok(BodyStep::Chunk(piece));
                }
                Framing::ChunkCrlf => match self.try_crlf_line()? {
                    None => return Ok(BodyStep::NeedMore),
                    Some(l) if l.is_empty() => *framing = Framing::ChunkSize,
                    Some(_) => bail!("chunk data overran its declared size"),
                },
                Framing::Trailer => match self.try_crlf_line()? {
                    None => return Ok(BodyStep::NeedMore),
                    Some(l) if l.is_empty() => {
                        *framing = Framing::Done;
                        return Ok(BodyStep::Done);
                    }
                    Some(_) => {} // discard trailer line, keep scanning
                },
            }
        }
    }

    /// Take up to `n` buffered bytes off the front (never more than
    /// `READ_CHUNK`, the streaming-chunk granularity contract).
    fn take_buffered(&mut self, n: usize) -> Vec<u8> {
        let take = n.min(self.buf.len()).min(READ_CHUNK);
        self.buf.drain(..take).collect()
    }

    /// Consume one CRLF-terminated line from the buffer if complete
    /// (`Ok(None)` = need more bytes), bounded to keep a hostile peer
    /// from ballooning the buffer.
    fn try_crlf_line(&mut self) -> Result<Option<String>> {
        if let Some(pos) = self.buf.windows(2).position(|w| w == b"\r\n") {
            let line = String::from_utf8(self.buf[..pos].to_vec())?;
            self.buf.drain(..pos + 2);
            self.scanned = 0;
            return Ok(Some(line));
        }
        if self.buf.len() > MAX_HEAD {
            bail!("chunk framing line too long");
        }
        Ok(None)
    }

    /// One non-blocking-friendly socket read into the buffer: no retry,
    /// no deadline logic (the reactor's timer wheel owns deadlines).
    /// `Ok(0)` = EOF; `WouldBlock` surfaces as the error it is.
    pub(crate) fn fill_once(&mut self) -> std::io::Result<usize> {
        let mut chunk = [0u8; READ_CHUNK];
        let n = self.stream.read(&mut chunk)?;
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(n)
    }

    /// Bytes currently buffered ahead of the parse cursor.
    pub(crate) fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Arm the request deadline at an absolute instant (the reactor
    /// hands a half-spent budget to the blocking ingest path this way).
    pub(crate) fn arm_deadline_at(&mut self, at: Instant) {
        self.deadline = Some(at);
    }

    /// Split into the raw stream + unconsumed buffered bytes (reactor ↔
    /// blocking-worker handoff).
    pub(crate) fn into_parts(self) -> (S, Vec<u8>) {
        (self.stream, self.buf)
    }

    /// Rebuild from [`Conn::into_parts`] output. No budget: deadlines
    /// are armed explicitly by the owner.
    pub(crate) fn from_parts(stream: S, buf: Vec<u8>) -> Conn<S> {
        Conn { stream, buf, scanned: 0, budget: None, deadline: None }
    }
}

/// Body framing state — shared by the blocking [`BodyReader`] and the
/// reactor's event-driven decode.
pub(crate) enum Framing {
    /// Content-Length framed: this many bytes left.
    Length { remaining: usize },
    /// Chunked: expecting a chunk-size line next.
    ChunkSize,
    /// Chunked: inside a chunk's data.
    ChunkData { remaining: usize },
    /// Chunked: expecting the CRLF that closes a chunk.
    ChunkCrlf,
    /// Chunked: in the trailer section after the 0-size chunk.
    Trailer,
    /// Fully consumed.
    Done,
}

impl Framing {
    /// Choose the framing for `head`. **Errors** on unframeable
    /// messages (unparsable Content-Length, a Transfer-Encoding other
    /// than chunked): the caller must respond 400 and close — guessing
    /// a framing would desynchronize the keep-alive stream.
    pub(crate) fn for_head(head: &Head) -> Result<Framing> {
        if let Some(te) = head.header("transfer-encoding") {
            let last = te.to_ascii_lowercase();
            let last = last.split(',').map(str::trim).next_back();
            if last == Some("chunked") {
                return Ok(Framing::ChunkSize);
            }
            bail!("unsupported Transfer-Encoding {te:?}");
        }
        Ok(match head.content_length()? {
            Some(n) if n > 0 => Framing::Length { remaining: n },
            _ => Framing::Done,
        })
    }

    pub(crate) fn is_done(&self) -> bool {
        matches!(self, Framing::Done)
    }
}

/// One step of event-driven body decoding (see [`Conn::decode_step`]).
pub(crate) enum BodyStep {
    /// A decoded payload piece (≤ `READ_CHUNK` bytes).
    Chunk(Vec<u8>),
    /// The buffer ran dry mid-body: wait for the next readable event.
    NeedMore,
    /// Body complete (trailers included, for chunked).
    Done,
}

/// Marker error for a body over [`MAX_BODY`]: downcast from the
/// `read_body_string` error to answer **413** rather than 400.
#[derive(Debug)]
pub struct BodyTooLarge(pub usize);

impl std::fmt::Display for BodyTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "body too large ({} bytes)", self.0)
    }
}

impl std::error::Error for BodyTooLarge {}

/// Streaming body: yields the payload as byte chunks of at most
/// `READ_CHUNK` bytes, decoding chunked transfer-encoding on the fly.
/// Also an `Iterator<Item = io::Result<Vec<u8>>>`, the shape
/// `crate::ingest::ChunkLexer` consumes.
pub struct BodyReader<'c, S: Read> {
    conn: &'c mut Conn<S>,
    framing: Framing,
}

impl<S: Read> BodyReader<'_, S> {
    /// Next piece of the decoded payload; `Ok(None)` when the body is
    /// fully consumed (trailers included, for chunked bodies).
    pub fn next_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        loop {
            match self.conn.decode_step(&mut self.framing)? {
                BodyStep::Chunk(piece) => return Ok(Some(piece)),
                BodyStep::Done => return Ok(None),
                BodyStep::NeedMore => {
                    if self.conn.fill()? == 0 {
                        bail!("connection closed mid-body");
                    }
                }
            }
        }
    }
}

impl<S: Read> Iterator for BodyReader<'_, S> {
    type Item = std::io::Result<Vec<u8>>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.next_chunk() {
            Ok(Some(c)) => Some(Ok(c)),
            Ok(None) => None,
            Err(e) => {
                self.framing = Framing::Done;
                Some(Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    e.to_string(),
                )))
            }
        }
    }
}

/// One-shot convenience (and the historic API): read a single request,
/// materializing its body.
pub fn read_request(stream: &mut impl Read) -> Result<Request> {
    let mut conn = Conn::new(stream);
    let head = conn
        .read_head()?
        .ok_or_else(|| anyhow!("connection closed mid-request"))?;
    let body = conn.read_body_string(&head)?;
    Ok(Request { method: head.method, path: head.path, headers: head.headers, body })
}

/// Find `\r\n\r\n` scanning only from `from` onwards (callers pass the
/// high-water mark of previous scans minus the 3-byte overlap).
fn find_head_end_from(buf: &[u8], from: usize) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    let from = from.min(buf.len() - 1);
    buf[from..].windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + from)
}

/// An HTTP response. Error responses carry the **v1 error envelope**
/// `{"error":{"code","message"}}` (see `docs/API.md`): `code` is a
/// stable machine-readable discriminant, `message` a human diagnostic
/// that may change between releases.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub reason: &'static str,
    pub body: String,
    /// `Content-Type` the body serializes under (`application/json` for
    /// every constructor except [`Response::ok_text`] — content
    /// negotiation on `/v1/metrics` serves Prometheus text through it).
    pub content_type: &'static str,
    /// Extra headers (`Retry-After`, `Allow`, `Deprecation`, ...)
    /// appended verbatim by [`Response::serialize_with`].
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn ok_json(body: crate::util::json::Json) -> Response {
        Response {
            status: 200,
            reason: "OK",
            body: body.to_string(),
            content_type: "application/json",
            headers: Vec::new(),
        }
    }

    /// A 200 with a non-JSON body (e.g. Prometheus text exposition).
    pub fn ok_text(content_type: &'static str, body: String) -> Response {
        Response { status: 200, reason: "OK", body, content_type, headers: Vec::new() }
    }

    /// An error response in the versioned envelope.
    pub fn error(status: u16, reason: &'static str, code: &str, message: &str) -> Response {
        use crate::util::json::Json;
        let body = Json::obj(vec![(
            "error",
            Json::obj(vec![("code", Json::str(code)), ("message", Json::str(message))]),
        )])
        .to_string();
        Response {
            status,
            reason,
            body,
            content_type: "application/json",
            headers: Vec::new(),
        }
    }

    /// Append a header (builder-style).
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }

    pub fn bad_request(msg: &str) -> Response {
        Response::error(400, "Bad Request", "invalid_request", msg)
    }

    /// Malformed resource id in the path (e.g. a non-u64 `{id}`).
    pub fn invalid_id(msg: &str) -> Response {
        Response::error(400, "Bad Request", "invalid_id", msg)
    }

    pub fn not_found() -> Response {
        Response::error(404, "Not Found", "not_found", "not found")
    }

    /// Known path, wrong method; `allow` lists the methods that work.
    pub fn method_not_allowed(allow: &str) -> Response {
        Response::error(
            405,
            "Method Not Allowed",
            "method_not_allowed",
            &format!("allowed: {allow}"),
        )
        .with_header("Allow", allow)
    }

    /// Per-request wall-clock deadline exceeded (slow-loris guard): the
    /// connection is closed after this is written.
    pub fn request_timeout() -> Response {
        Response::error(408, "Request Timeout", "request_timeout", "request deadline exceeded")
    }

    /// Materialized body over [`MAX_BODY`].
    pub fn payload_too_large(msg: &str) -> Response {
        Response::error(413, "Payload Too Large", "payload_too_large", msg)
    }

    /// The paper's 'busy' status: both queues full. Callers with queue
    /// visibility add `Retry-After` via [`Response::with_header`].
    pub fn busy() -> Response {
        Response::error(503, "Service Unavailable", "busy", "busy")
    }

    pub fn server_error(msg: &str) -> Response {
        Response::error(500, "Internal Server Error", "internal", msg)
    }

    /// Serialize closing the connection (the historic behavior).
    pub fn serialize(&self) -> String {
        self.serialize_with(false)
    }

    /// Serialize with an explicit connection disposition: `keep-alive`
    /// lets the client reuse the connection for its next request.
    pub fn serialize_with(&self, keep_alive: bool) -> String {
        let mut out = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            self.reason,
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (name, value) in &self.headers {
            out.push_str(name);
            out.push_str(": ");
            out.push_str(value);
            out.push_str("\r\n");
        }
        out.push_str("\r\n");
        out.push_str(&self.body);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = "POST /v1/embed HTTP/1.1\r\nHost: x\r\nContent-Length: 17\r\n\r\n{\"texts\":[\"abc\"]}";
        let req = read_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/embed");
        assert_eq!(req.body, "{\"texts\":[\"abc\"]}");
        assert_eq!(req.headers.get("host").map(|s| s.as_str()), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.body, "");
    }

    #[test]
    fn rejects_malformed_request_line() {
        let raw = "NONSENSE\r\n\r\n";
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn rejects_truncated_body() {
        let raw = "POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\nshort";
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    /// The keep-alive satellite: bytes past the first message are the
    /// next request, not garbage to truncate.
    #[test]
    fn pipelined_requests_survive_in_the_conn_buffer() {
        let raw = "POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nabcGET /b HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let h1 = conn.read_head().unwrap().unwrap();
        assert_eq!(h1.path, "/a");
        assert_eq!(conn.read_body_string(&h1).unwrap(), "abc");
        let h2 = conn.read_head().unwrap().unwrap();
        assert_eq!(h2.path, "/b");
        assert_eq!(conn.read_body_string(&h2).unwrap(), "");
        // Clean EOF between requests.
        assert!(conn.read_head().unwrap().is_none());
    }

    #[test]
    fn keep_alive_defaults_follow_http_version() {
        let mk = |line: &str, conn_header: Option<&str>| {
            let mut headers = HashMap::new();
            if let Some(c) = conn_header {
                headers.insert("connection".to_string(), c.to_string());
            }
            Head {
                method: "GET".into(),
                path: "/".into(),
                headers,
                http11: line != "HTTP/1.0",
            }
        };
        assert!(mk("HTTP/1.1", None).wants_keep_alive());
        assert!(!mk("HTTP/1.0", None).wants_keep_alive());
        assert!(mk("HTTP/1.0", Some("keep-alive")).wants_keep_alive());
        assert!(!mk("HTTP/1.1", Some("close")).wants_keep_alive());
    }

    #[test]
    fn chunked_body_decodes_across_reads() {
        let raw = "POST /v1/corpus HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                   4\r\nWiki\r\n7\r\npedia i\r\nB\r\nn chunks.\r\n\r\n0\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        assert!(head.chunked());
        let body = conn.read_body_string(&head).unwrap();
        assert_eq!(body, "Wikipedia in chunks.\r\n");
    }

    #[test]
    fn chunked_body_streams_as_iterator() {
        let raw = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                   3\r\nabc\r\n3\r\ndef\r\n0\r\n\r\nGET /next HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        let pieces: Vec<Vec<u8>> = conn.body(&head).unwrap().map(|c| c.unwrap()).collect();
        let flat: Vec<u8> = pieces.into_iter().flatten().collect();
        assert_eq!(flat, b"abcdef");
        // The next pipelined request is intact after the chunked body.
        let h2 = conn.read_head().unwrap().unwrap();
        assert_eq!(h2.path, "/next");
    }

    /// The smuggling fix: an unparsable Content-Length (or a
    /// Transfer-Encoding we cannot decode) is a framing error, never
    /// "no body" — otherwise the body bytes would be reparsed as the
    /// next keep-alive request.
    #[test]
    fn unframeable_messages_error_instead_of_desyncing() {
        let raw = "POST /v1/embed HTTP/1.1\r\nContent-Length: 18446744073709551616\r\n\r\nGET /smuggled HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        assert!(head.content_length().is_err());
        assert!(conn.read_body_string(&head).is_err());

        let raw = "POST / HTTP/1.1\r\nContent-Length: 5, 5\r\n\r\nhello";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        assert!(conn.body(&head).is_err());

        let raw = "POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\nxxxx";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        assert!(conn.body(&head).is_err());

        // `Transfer-Encoding: gzip, chunked` is decodable framing-wise
        // (chunked is the outermost/last coding).
        let raw = "POST / HTTP/1.1\r\nTransfer-Encoding: gzip, chunked\r\n\r\n3\r\nabc\r\n0\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        assert_eq!(conn.read_body_string(&head).unwrap(), "abc");
    }

    #[test]
    fn chunked_rejects_bad_size_lines() {
        let raw = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\nabc\r\n0\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        assert!(conn.read_body_string(&head).is_err());
    }

    #[test]
    fn head_scan_is_incremental_across_tiny_reads() {
        // A reader that trickles one byte per read: correctness of the
        // tail-window scan (the perf satellite's behavior contract).
        struct OneByte<'a>(&'a [u8], usize);
        impl Read for OneByte<'_> {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Ok(0);
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let raw = b"GET /slow HTTP/1.1\r\nX-Long: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n\r\n";
        let mut conn = Conn::new(OneByte(raw, 0));
        let head = conn.read_head().unwrap().unwrap();
        assert_eq!(head.path, "/slow");
        assert_eq!(head.header("x-long").unwrap().len(), 30);
    }

    #[test]
    fn response_serialises_with_content_length() {
        let r = Response::ok_json(crate::util::json::Json::Bool(true));
        let s = r.serialize();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 4"));
        assert!(s.contains("Connection: close"));
        assert!(s.ends_with("true"));
        let k = r.serialize_with(true);
        assert!(k.contains("Connection: keep-alive"));
    }

    #[test]
    fn busy_is_503() {
        assert_eq!(Response::busy().status, 503);
    }

    #[test]
    fn text_responses_carry_their_content_type() {
        let r = Response::ok_text("text/plain; version=0.0.4", "x 1\n".into());
        let s = r.serialize();
        assert!(s.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(s.ends_with("x 1\n"));
        // JSON constructors are unchanged.
        let j = Response::ok_json(crate::util::json::Json::Bool(true)).serialize();
        assert!(j.contains("Content-Type: application/json"));
    }

    /// One byte per read: the trickling head that per-read timeouts
    /// never catch. With a zero budget the wall-clock deadline arms on
    /// the first byte and trips on the next fill.
    #[test]
    fn slow_loris_head_trips_the_request_deadline() {
        struct OneByte<'a>(&'a [u8], usize);
        impl Read for OneByte<'_> {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Ok(0);
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let raw = b"GET /slow HTTP/1.1\r\n\r\n";
        let mut conn = Conn::with_budget(OneByte(raw, 0), Duration::ZERO);
        assert!(conn.read_head().is_err());
        assert!(conn.deadline_exceeded(), "the 408 signal");
        // Without a budget the same trickle parses fine.
        let mut conn = Conn::new(OneByte(raw, 0));
        assert_eq!(conn.read_head().unwrap().unwrap().path, "/slow");
        assert!(!conn.deadline_exceeded());
    }

    /// A trickling *body* is caught too: the deadline spans head + body,
    /// not just the head scan.
    #[test]
    fn slow_loris_body_trips_the_request_deadline() {
        struct HeadThenTrickle(Vec<u8>, usize, usize);
        impl Read for HeadThenTrickle {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Ok(0);
                }
                // First read hands over the whole head, then 1 byte/read.
                let n = if self.1 == 0 { self.2 } else { 1 };
                let n = n.min(buf.len()).min(self.0.len() - self.1);
                buf[..n].copy_from_slice(&self.0[self.1..self.1 + n]);
                self.1 += n;
                Ok(n)
            }
        }
        let head = b"POST /v1/corpus HTTP/1.1\r\nContent-Length: 5\r\n\r\n".to_vec();
        let head_len = head.len();
        let mut raw = head;
        raw.extend_from_slice(b"hello");
        let mut conn =
            Conn::with_budget(HeadThenTrickle(raw, 0, head_len), Duration::ZERO);
        let h = conn.read_head().unwrap().unwrap();
        assert!(conn.read_body_string(&h).is_err());
        assert!(conn.deadline_exceeded());
    }

    /// `finish_request` stops the clock: a served request's spent budget
    /// never bleeds into the idle keep-alive wait or the next request.
    #[test]
    fn finish_request_disarms_the_deadline() {
        let raw = b"GET /a HTTP/1.1\r\n\r\n";
        let mut conn = Conn::with_budget(Cursor::new(raw.as_slice()), Duration::ZERO);
        let h = conn.read_head().unwrap().unwrap();
        assert_eq!(h.path, "/a");
        assert!(conn.deadline_exceeded(), "zero budget: armed and already past");
        conn.finish_request();
        assert!(!conn.deadline_exceeded());
        // Idle close (EOF with an empty buffer) still reads cleanly.
        assert!(conn.read_head().unwrap().is_none());
    }

    #[test]
    fn request_timeout_is_408() {
        assert_eq!(Response::request_timeout().status, 408);
    }

    /// Every error constructor emits the v1 envelope:
    /// `{"error":{"code","message"}}` with the documented code.
    #[test]
    fn error_responses_carry_the_versioned_envelope() {
        use crate::util::json;
        let cases = [
            (Response::bad_request("nope"), 400, "invalid_request"),
            (Response::invalid_id("id must be a u64"), 400, "invalid_id"),
            (Response::not_found(), 404, "not_found"),
            (Response::method_not_allowed("GET"), 405, "method_not_allowed"),
            (Response::request_timeout(), 408, "request_timeout"),
            (Response::payload_too_large("too big"), 413, "payload_too_large"),
            (Response::busy(), 503, "busy"),
            (Response::server_error("boom"), 500, "internal"),
        ];
        for (resp, status, code) in cases {
            assert_eq!(resp.status, status);
            let v = json::parse(&resp.body).unwrap();
            let err = v.get("error").expect("envelope object");
            assert_eq!(err.get("code").and_then(|c| c.as_str()), Some(code));
            assert!(err.get("message").and_then(|m| m.as_str()).is_some());
        }
    }

    #[test]
    fn extra_headers_serialize_before_the_body() {
        let s = Response::busy().with_header("Retry-After", "2").serialize();
        let head_end = s.find("\r\n\r\n").unwrap();
        assert!(s[..head_end].contains("Retry-After: 2"));
        assert!(s[..head_end].contains("Connection: close"));
        let allow = Response::method_not_allowed("GET, POST").serialize();
        assert!(allow[..allow.find("\r\n\r\n").unwrap()].contains("Allow: GET, POST"));
    }

    #[test]
    fn oversize_bodies_downcast_to_body_too_large() {
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        let mut cur = Cursor::new(raw.into_bytes());
        let mut conn = Conn::new(&mut cur);
        let head = conn.read_head().unwrap().unwrap();
        let err = conn.read_body_string(&head).unwrap_err();
        assert!(err.downcast_ref::<BodyTooLarge>().is_some());
    }

    /// The event-driven decode: feeding bytes a few at a time through
    /// `try_parse_head` + `decode_step` (no socket fills) produces the
    /// same head and body the blocking path would.
    #[test]
    fn incremental_parse_matches_blocking_for_chunked_bodies() {
        let raw: &[u8] = b"POST /v1/corpus HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                           4\r\nWiki\r\n7\r\npedia i\r\nB\r\nn chunks.\r\n\r\n0\r\n\r\n";
        // An empty cursor: the conn never gets bytes from its stream;
        // we append to its buffer by hand to simulate readiness events.
        let mut conn = Conn::new(Cursor::new(Vec::<u8>::new()));
        let mut fed = 0usize;
        let mut head = None;
        while head.is_none() {
            assert!(fed < raw.len(), "head never parsed");
            let step = (raw.len() - fed).min(7);
            conn.buf.extend_from_slice(&raw[fed..fed + step]);
            fed += step;
            head = conn.try_parse_head().unwrap();
        }
        let head = head.unwrap();
        assert!(head.chunked());
        let mut framing = Framing::for_head(&head).unwrap();
        let mut body = Vec::new();
        loop {
            match conn.decode_step(&mut framing).unwrap() {
                BodyStep::Chunk(c) => body.extend_from_slice(&c),
                BodyStep::Done => break,
                BodyStep::NeedMore => {
                    assert!(fed < raw.len(), "body never completed");
                    let step = (raw.len() - fed).min(7);
                    conn.buf.extend_from_slice(&raw[fed..fed + step]);
                    fed += step;
                }
            }
        }
        assert!(framing.is_done());
        assert_eq!(body, b"Wikipedia in chunks.\r\n");
    }

    #[test]
    fn incremental_parse_handles_content_length_and_pipelining() {
        let raw: &[u8] = b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nabcGET /b HTTP/1.1\r\n\r\n";
        let mut conn = Conn::new(Cursor::new(Vec::<u8>::new()));
        conn.buf.extend_from_slice(raw);
        let h1 = conn.try_parse_head().unwrap().unwrap();
        assert_eq!(h1.path, "/a");
        let mut framing = Framing::for_head(&h1).unwrap();
        let mut body = Vec::new();
        loop {
            match conn.decode_step(&mut framing).unwrap() {
                BodyStep::Chunk(c) => body.extend_from_slice(&c),
                BodyStep::Done => break,
                BodyStep::NeedMore => panic!("fully buffered body asked for more"),
            }
        }
        assert_eq!(body, b"abc");
        // The pipelined request is intact behind the body.
        let h2 = conn.try_parse_head().unwrap().unwrap();
        assert_eq!(h2.path, "/b");
        assert_eq!(conn.buffered(), 0);
    }
}
