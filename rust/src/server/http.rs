//! HTTP/1.1 request parsing and response serialisation (no framework).

use std::collections::HashMap;
use std::io::Read;

use anyhow::{bail, Result};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: String,
}

/// Maximum request size we accept (embedding batches are small).
const MAX_BODY: usize = 4 * 1024 * 1024;
const MAX_HEAD: usize = 64 * 1024;

/// Read a full request from the stream (blocking, Content-Length framed).
pub fn read_request(stream: &mut impl Read) -> Result<Request> {
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let head_end;
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&chunk[..n]);
        if let Some(pos) = find_head_end(&buf) {
            head_end = pos;
            break;
        }
        if buf.len() > MAX_HEAD {
            bail!("headers too large");
        }
    }

    let head = std::str::from_utf8(&buf[..head_end])?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line: {request_line:?}");
    }

    let mut headers = HashMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let content_len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if content_len > MAX_BODY {
        bail!("body too large ({content_len} bytes)");
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_len);
    Ok(Request {
        method,
        path,
        headers,
        body: String::from_utf8(body)?,
    })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// An HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub reason: &'static str,
    pub body: String,
}

impl Response {
    pub fn ok_json(body: crate::util::json::Json) -> Response {
        Response { status: 200, reason: "OK", body: body.to_string() }
    }

    pub fn bad_request(msg: &str) -> Response {
        Response {
            status: 400,
            reason: "Bad Request",
            body: err_body(msg),
        }
    }

    pub fn not_found() -> Response {
        Response { status: 404, reason: "Not Found", body: err_body("not found") }
    }

    /// The paper's 'busy' status: both queues full.
    pub fn busy() -> Response {
        Response {
            status: 503,
            reason: "Service Unavailable",
            body: err_body("busy"),
        }
    }

    pub fn server_error(msg: &str) -> Response {
        Response {
            status: 500,
            reason: "Internal Server Error",
            body: err_body(msg),
        }
    }

    pub fn serialize(&self) -> String {
        format!(
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            self.status,
            self.reason,
            self.body.len(),
            self.body
        )
    }
}

fn err_body(msg: &str) -> String {
    crate::util::json::Json::obj(vec![(
        "error",
        crate::util::json::Json::str(msg),
    )])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = "POST /v1/embed HTTP/1.1\r\nHost: x\r\nContent-Length: 17\r\n\r\n{\"texts\":[\"abc\"]}";
        let req = read_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/embed");
        assert_eq!(req.body, "{\"texts\":[\"abc\"]}");
        assert_eq!(req.headers.get("host").map(|s| s.as_str()), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.body, "");
    }

    #[test]
    fn rejects_malformed_request_line() {
        let raw = "NONSENSE\r\n\r\n";
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn rejects_truncated_body() {
        let raw = "POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\nshort";
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn response_serialises_with_content_length() {
        let r = Response::ok_json(crate::util::json::Json::Bool(true));
        let s = r.serialize();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 4"));
        assert!(s.ends_with("true"));
    }

    #[test]
    fn busy_is_503() {
        assert_eq!(Response::busy().status, 503);
    }
}
