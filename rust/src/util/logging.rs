//! Minimal `log` backend writing to stderr.
//!
//! Level comes from `WINDVE_LOG` (error|warn|info|debug|trace, default
//! info). Install once with [`init`].

use std::io::Write;
use std::sync::Once;

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let _ = writeln!(
                std::io::stderr(),
                "[{:<5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the stderr logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("WINDVE_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            _ => log::LevelFilter::Info,
        };
        let logger = Box::new(StderrLogger { level });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
