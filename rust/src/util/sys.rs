//! Minimal hand-rolled OS FFI — the offline vendor set has no `libc`
//! crate, so the few syscalls the runtime needs are declared here
//! directly against the platform C library (which every std binary
//! already links).
//!
//! Three surfaces:
//!
//! * **CPU affinity** (`sched_setaffinity`/`sched_getaffinity`) for the
//!   paper's §4.4 NUMA pinning — Linux only; no-ops elsewhere.
//! * **Readiness polling** for the server reactor: `epoll` on Linux,
//!   `poll(2)` on other unixes. Non-unix targets fall back to the
//!   threaded server and never reach these.
//! * **`RLIMIT_NOFILE`** introspection/raising, so the connection-soak
//!   harness can open hundreds of sockets under default shell limits.
//!
//! Every wrapper converts `-1` into `io::Error::last_os_error()`; no
//! errno handling leaks to callers.

#![allow(non_camel_case_types)]

#[cfg(unix)]
use std::io;

// ---------------------------------------------------------------------------
// CPU affinity (Linux).
// ---------------------------------------------------------------------------

/// `cpu_set_t` as a plain 1024-bit mask (16 × u64) — the glibc layout.
#[cfg(target_os = "linux")]
pub type CpuSet = [u64; 16];

/// Bits in [`CpuSet`].
#[cfg(target_os = "linux")]
pub const CPU_SETSIZE: usize = 1024;

#[cfg(target_os = "linux")]
extern "C" {
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
}

/// Pin the calling thread to `cores`. Errors mirror `sched_setaffinity`.
#[cfg(target_os = "linux")]
pub fn set_thread_affinity(cores: &[usize]) -> io::Result<()> {
    let mut set: CpuSet = [0; 16];
    for &c in cores {
        if c < CPU_SETSIZE {
            set[c / 64] |= 1u64 << (c % 64);
        }
    }
    // SAFETY: `set` is a live, initialized `[u64; 16]` and the size
    // argument is exactly its byte length, so the kernel reads only
    // memory we own; pid 0 means "calling thread" (no aliasing hazard).
    let rc = unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), set.as_ptr()) };
    if rc != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// The calling thread's allowed cores.
#[cfg(target_os = "linux")]
pub fn get_thread_affinity() -> io::Result<Vec<usize>> {
    let mut set: CpuSet = [0; 16];
    // SAFETY: `set` is a live `[u64; 16]` we exclusively own and the size
    // argument is exactly its byte length, so the kernel writes only
    // inside it (and `u64` has no invalid bit patterns).
    let rc = unsafe { sched_getaffinity(0, std::mem::size_of::<CpuSet>(), set.as_mut_ptr()) };
    if rc != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok((0..CPU_SETSIZE).filter(|&c| set[c / 64] & (1u64 << (c % 64)) != 0).collect())
}

// ---------------------------------------------------------------------------
// epoll (Linux) — the reactor's readiness source.
// ---------------------------------------------------------------------------

/// Readable-interest bit (also used by the portable poller facade).
#[cfg(target_os = "linux")]
pub const EPOLLIN: u32 = 0x001;
#[cfg(target_os = "linux")]
pub const EPOLLOUT: u32 = 0x004;
#[cfg(target_os = "linux")]
pub const EPOLLERR: u32 = 0x008;
#[cfg(target_os = "linux")]
pub const EPOLLHUP: u32 = 0x010;
#[cfg(target_os = "linux")]
pub const EPOLLRDHUP: u32 = 0x2000;

#[cfg(target_os = "linux")]
pub const EPOLL_CTL_ADD: i32 = 1;
#[cfg(target_os = "linux")]
pub const EPOLL_CTL_DEL: i32 = 2;
#[cfg(target_os = "linux")]
pub const EPOLL_CTL_MOD: i32 = 3;

#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: i32 = 0o2000000;

/// Kernel `struct epoll_event` — packed on x86 so the 12-byte layout
/// matches the ABI (aligned elsewhere).
#[cfg(target_os = "linux")]
#[repr(C)]
#[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[cfg(target_os = "linux")]
extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn close(fd: i32) -> i32;
}

/// Owned epoll instance (closed on drop).
#[cfg(target_os = "linux")]
pub struct Epoll {
    fd: i32,
}

#[cfg(target_os = "linux")]
impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        // SAFETY: `epoll_create1` takes no pointers; it returns a fresh
        // fd (owned by the `Epoll` below) or -1.
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    pub fn ctl(&self, op: i32, fd: i32, events: u32, data: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data };
        // SAFETY: `ev` is a live, initialized `repr(C)` value matching the
        // kernel's `struct epoll_event` layout; the kernel only reads it
        // during the call and keeps no reference afterwards.
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Wait for events; `timeout_ms < 0` blocks indefinitely. Fills
    /// `out` (caller-sized) and returns the event count. `EINTR`
    /// surfaces as `Ok(0)` — the reactor loop just re-polls.
    pub fn wait(&self, out: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        // Clamp rather than cast: a buffer above i32::MAX entries would
        // otherwise wrap `maxevents` negative (EINVAL at best, and the
        // `n as usize` bound below would no longer cover the slice).
        let cap = out.len().min(i32::MAX as usize) as i32;
        // SAFETY: `out` is exclusively borrowed and `maxevents == cap` is
        // clamped to its length, so the kernel writes at most `cap`
        // events inside the slice; `EpollEvent` is plain-old-data, so any
        // bytes the kernel writes are valid values. On success
        // `0 <= n <= cap`, keeping `out[..n]` in bounds for callers.
        let n = unsafe { epoll_wait(self.fd, out.as_mut_ptr(), cap, timeout_ms) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }
}

#[cfg(target_os = "linux")]
impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` was returned by `epoll_create1`, is owned
        // exclusively by this struct, and is closed exactly once (drop
        // runs once); no pointers are involved.
        unsafe {
            close(self.fd);
        }
    }
}

// ---------------------------------------------------------------------------
// poll(2) (non-Linux unix) — the portable readiness fallback.
// ---------------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

#[cfg(all(unix, not(target_os = "linux")))]
pub const POLLIN: i16 = 0x001;
#[cfg(all(unix, not(target_os = "linux")))]
pub const POLLOUT: i16 = 0x004;
#[cfg(all(unix, not(target_os = "linux")))]
pub const POLLERR: i16 = 0x008;
#[cfg(all(unix, not(target_os = "linux")))]
pub const POLLHUP: i16 = 0x010;

#[cfg(all(unix, not(target_os = "linux")))]
extern "C" {
    fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
}

/// `poll(2)`; `timeout_ms < 0` blocks. `EINTR` → `Ok(0)`.
#[cfg(all(unix, not(target_os = "linux")))]
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    // Clamp rather than cast so an oversized slice cannot silently
    // truncate `nfds` (u32 on every supported libc).
    let nfds = fds.len().min(u32::MAX as usize) as u32;
    // SAFETY: `fds` is exclusively borrowed and `nfds` is clamped to its
    // length, so the kernel reads/writes only the `revents` fields of
    // entries inside the slice; `PollFd` is plain-old-data matching the
    // libc `struct pollfd` layout.
    let n = unsafe { poll(fds.as_mut_ptr(), nfds, timeout_ms) };
    if n < 0 {
        let e = io::Error::last_os_error();
        if e.kind() == io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(e);
    }
    Ok(n as usize)
}

// ---------------------------------------------------------------------------
// RLIMIT_NOFILE — soak-test fd headroom.
// ---------------------------------------------------------------------------

#[cfg(unix)]
#[repr(C)]
#[derive(Clone, Copy)]
struct Rlimit {
    rlim_cur: u64,
    rlim_max: u64,
}

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: i32 = 7;
#[cfg(all(unix, not(target_os = "linux")))]
const RLIMIT_NOFILE: i32 = 8; // BSD/macOS numbering

#[cfg(unix)]
extern "C" {
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
}

/// Raise the soft open-file limit toward `want` (bounded by the hard
/// limit) and return the soft limit now in effect. Best-effort: on any
/// failure the current soft limit is returned unchanged.
#[cfg(unix)]
pub fn raise_nofile_limit(want: u64) -> u64 {
    // SAFETY: both calls pass a pointer to a live, initialized `Rlimit`
    // on this stack frame, matching the libc `struct rlimit` layout
    // (two u64s on the supported 64-bit unixes); `getrlimit` writes only
    // inside it and `setrlimit` only reads it.
    unsafe {
        let mut lim = Rlimit { rlim_cur: 0, rlim_max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        if lim.rlim_cur >= want {
            return lim.rlim_cur;
        }
        let target = want.min(lim.rlim_max);
        let newlim = Rlimit { rlim_cur: target, rlim_max: lim.rlim_max };
        if setrlimit(RLIMIT_NOFILE, &newlim) == 0 {
            target
        } else {
            lim.rlim_cur
        }
    }
}

#[cfg(not(unix))]
pub fn raise_nofile_limit(_want: u64) -> u64 {
    u64::MAX // no fd rlimits on this target
}

#[cfg(test)]
mod tests {
    #[cfg(target_os = "linux")]
    #[test]
    fn affinity_roundtrip_via_raw_ffi() {
        let all = super::get_thread_affinity().unwrap();
        assert!(!all.is_empty());
        super::set_thread_affinity(&all).unwrap();
        assert_eq!(super::get_thread_affinity().unwrap(), all);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_reports_readable_pipe_end() {
        use super::*;
        use std::io::Write;
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;

        // A loopback pair stands in for a pipe (no pipe2 FFI needed).
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (rx, _) = l.accept().unwrap();

        let ep = Epoll::new().unwrap();
        ep.ctl(EPOLL_CTL_ADD, rx.as_raw_fd(), EPOLLIN, 42).unwrap();
        let mut evs = [EpollEvent { events: 0, data: 0 }; 8];
        // Nothing written yet: a short wait sees no events.
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        tx.write_all(b"x").unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        let ev = evs[0];
        assert_eq!({ ev.data }, 42);
        assert_ne!({ ev.events } & EPOLLIN, 0);
    }

    #[cfg(unix)]
    #[test]
    fn nofile_limit_is_positive() {
        assert!(super::raise_nofile_limit(256) >= 256 || super::raise_nofile_limit(1) >= 1);
    }
}
