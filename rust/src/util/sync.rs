//! Loom-switchable synchronization primitives.
//!
//! Modules whose concurrency protocols are model-checked (the admission
//! queue manager, the executor's version/mirror handshake, the embedding
//! cache) import their sync types from here instead of `std::sync`. A
//! normal build re-exports `std::sync` verbatim — zero cost, identical
//! types. Under `RUSTFLAGS="--cfg loom"` the same paths resolve to
//! [`loom`](https://docs.rs/loom)'s permutation-exploring mocks, so the
//! loom suites in `tests/loom/` can exhaustively run every interleaving
//! of those protocols (see `docs/VERIFICATION.md`).
//!
//! What belongs here: types participating in a protocol a loom test
//! drives. What does not: one-shot detection caches (e.g. the SIMD
//! `ACTIVE` cell in `vecstore::kernels`, which must live in a `static` —
//! loom atomics have no `const fn new`), plain `Arc<str>` data sharing,
//! and `mpsc` channels loom does not model.
//!
//! The `xtask lint` pass (`std-sync-import` rule) enforces that migrated
//! modules never quietly regress to direct `std::sync` primitives.

#[cfg(not(loom))]
pub use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(loom)]
pub use loom::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// `std::sync::atomic` (or `loom::sync::atomic` under `cfg(loom)`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Thread spawn/yield for tests that drive the shimmed types; loom's
/// versions participate in the model's schedule exploration.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};
}
