//! Infrastructure substrates built in-repo (the offline vendor set has no
//! serde_json/clap/rand/tokio — see DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod sync;
pub mod sys;
pub mod threadpool;
