//! Fixed-size thread pool (tokio is unavailable offline; the serving path
//! uses dedicated worker threads plus this pool for connection handling).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A bounded pool of OS threads consuming jobs from a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (must be > 0).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "pool size must be > 0");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("windve-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    /// Submit a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                // ordering: Relaxed — fetch_add is atomic on its own, and
                // the pool join below synchronizes-with every worker
                // before the final load (SeqCst bought nothing here).
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(4);
        let (tx, rx) = mpsc::channel();
        for i in 0..4 {
            let tx = tx.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                tx.send(i).unwrap();
            });
        }
        let start = std::time::Instant::now();
        for _ in 0..4 {
            rx.recv_timeout(std::time::Duration::from_secs(2)).unwrap();
        }
        // Serial would take >= 80ms.
        assert!(start.elapsed() < std::time::Duration::from_millis(75));
    }

    #[test]
    #[should_panic]
    fn zero_size_panics() {
        let _ = ThreadPool::new(0);
    }
}
