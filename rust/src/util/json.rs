//! Minimal JSON parser/writer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar; objects preserve insertion order.
//! Numbers are f64 (adequate for manifests, metrics and the HTTP API).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Serialise to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{}", n));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            kvs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(kvs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Convert a map into a Json object (sorted keys — handy for stable output).
pub fn from_map(map: &BTreeMap<String, Json>) -> Json {
    Json::Obj(map.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.path("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
        let v = parse("\"日本語\"").unwrap();
        assert_eq!(v.as_str(), Some("日本語"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":1.5,"y":[true,false,null],"s":"a\"b","n":-7}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn object_preserves_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse("[ ]").unwrap(), Json::Arr(vec![]));
    }
}
