//! Deterministic PRNG (PCG-XSH-RR 64/32 + helpers).
//!
//! All stochastic components (simulator noise, workload generation,
//! property tests) take an explicit [`Pcg`] so every run is reproducible
//! from a printed seed.

/// PCG-XSH-RR 64/32 with a fixed stream; small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
}

const MULT: u64 = 6364136223846793005;
const INC: u64 = 1442695040888963407;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg { state: seed.wrapping_add(INC) };
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(INC);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(mu, sigma).
    pub fn gauss(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut rng = Pcg::new(9);
        for _ in 0..10_000 {
            let x = rng.range(5, 15);
            assert!((5..15).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut rng = Pcg::new(11);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn uniformity_chi_square_sane() {
        let mut rng = Pcg::new(13);
        let mut buckets = [0usize; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[rng.usize(0, 16)] += 1;
        }
        let expected = n as f64 / 16.0;
        let chi2: f64 = buckets
            .iter()
            .map(|&o| (o as f64 - expected).powi(2) / expected)
            .sum();
        assert!(chi2 < 50.0, "chi2 {chi2}"); // 15 dof, wildly generous bound
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut rng = Pcg::new(19);
        let n = 50_000;
        let mean = (0..n).map(|_| rng.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
