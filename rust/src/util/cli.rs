//! Tiny argument parser (clap is unavailable offline).
//!
//! Grammar: `windve <subcommand> [--key value]... [--flag]... [positional]...`
//! Option keys are normalised (leading `--` stripped); `--key=value` works.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(arg);
            } else {
                args.positional.push(arg);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self
                .str_opt(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["serve", "extra1", "extra2"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn options_with_space_and_equals() {
        let a = parse(&["run", "--model", "bge_micro", "--slo=1.5"]);
        assert_eq!(a.str_opt("model"), Some("bge_micro"));
        assert_eq!(a.f64_or("slo", 0.0), 1.5);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["run", "--hetero", "--model", "x", "--verbose"]);
        assert!(a.flag("hetero"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("missing"));
        assert_eq!(a.str_opt("model"), Some("x"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.usize_or("depth", 7), 7);
        assert_eq!(a.str_or("name", "d"), "d");
        assert_eq!(a.u64_or("seed", 3), 3);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--devices", "v100, xeon ,atlas"]);
        assert_eq!(a.list_or("devices", &[]), vec!["v100", "xeon", "atlas"]);
        assert_eq!(a.list_or("other", &["a"]), vec!["a"]);
    }

    #[test]
    fn flag_via_value() {
        let a = parse(&["x", "--hetero", "true", "--off", "0"]);
        assert!(a.flag("hetero"));
        assert!(!a.flag("off"));
    }
}
