//! Serving configuration: JSON config file + CLI overrides.
//!
//! ```json
//! {
//!   "model": "bge_micro",
//!   "artifacts": "artifacts",
//!   "slo_seconds": 1.0,
//!   "hetero": true,
//!   "npu_depth": 44, "cpu_depth": 8,
//!   "npu_workers": 1, "cpu_workers": 1,
//!   "listen": "127.0.0.1:8316",
//!   "pin_cpu_cores": 8
//! }
//! ```

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub model: String,
    pub artifacts: PathBuf,
    pub slo_seconds: f64,
    pub hetero: bool,
    pub npu_depth: usize,
    pub cpu_depth: usize,
    pub npu_workers: usize,
    pub cpu_workers: usize,
    pub listen: String,
    /// Cores to pin the CPU instance to (0 = no pinning), picked
    /// reversed/NUMA-local per paper §4.4.
    pub pin_cpu_cores: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            model: "bge_micro".into(),
            artifacts: PathBuf::from("artifacts"),
            slo_seconds: 1.0,
            hetero: true,
            npu_depth: 44,
            cpu_depth: 8,
            npu_workers: 1,
            cpu_workers: 1,
            listen: "127.0.0.1:8316".into(),
            pin_cpu_cores: 0,
        }
    }
}

impl Config {
    /// Load from a JSON file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let root = json::parse(&text).context("parse config json")?;
        Ok(Self::from_json(&root))
    }

    pub fn from_json(root: &Json) -> Config {
        let d = Config::default();
        let gs = |k: &str, dv: &str| {
            root.get(k).and_then(Json::as_str).unwrap_or(dv).to_string()
        };
        Config {
            model: gs("model", &d.model),
            artifacts: PathBuf::from(gs("artifacts", &d.artifacts.to_string_lossy())),
            slo_seconds: root.get("slo_seconds").and_then(Json::as_f64).unwrap_or(d.slo_seconds),
            hetero: root.get("hetero").and_then(Json::as_bool).unwrap_or(d.hetero),
            npu_depth: root.get("npu_depth").and_then(Json::as_usize).unwrap_or(d.npu_depth),
            cpu_depth: root.get("cpu_depth").and_then(Json::as_usize).unwrap_or(d.cpu_depth),
            npu_workers: root.get("npu_workers").and_then(Json::as_usize).unwrap_or(d.npu_workers),
            cpu_workers: root.get("cpu_workers").and_then(Json::as_usize).unwrap_or(d.cpu_workers),
            listen: gs("listen", &d.listen),
            pin_cpu_cores: root
                .get("pin_cpu_cores")
                .and_then(Json::as_usize)
                .unwrap_or(d.pin_cpu_cores),
        }
    }

    /// Apply CLI overrides (`--model`, `--slo`, `--npu-depth`, ...).
    pub fn apply_args(mut self, args: &Args) -> Config {
        if let Some(m) = args.str_opt("model") {
            self.model = m.to_string();
        }
        if let Some(a) = args.str_opt("artifacts") {
            self.artifacts = PathBuf::from(a);
        }
        self.slo_seconds = args.f64_or("slo", self.slo_seconds);
        if args.flag("hetero") {
            self.hetero = true;
        }
        if args.flag("no-hetero") {
            self.hetero = false;
        }
        self.npu_depth = args.usize_or("npu-depth", self.npu_depth);
        self.cpu_depth = args.usize_or("cpu-depth", self.cpu_depth);
        self.npu_workers = args.usize_or("npu-workers", self.npu_workers);
        self.cpu_workers = args.usize_or("cpu-workers", self.cpu_workers);
        if let Some(l) = args.str_opt("listen") {
            self.listen = l.to_string();
        }
        self.pin_cpu_cores = args.usize_or("pin-cpu-cores", self.pin_cpu_cores);
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("artifacts", Json::str(self.artifacts.to_string_lossy())),
            ("slo_seconds", Json::num(self.slo_seconds)),
            ("hetero", Json::Bool(self.hetero)),
            ("npu_depth", Json::num(self.npu_depth as f64)),
            ("cpu_depth", Json::num(self.cpu_depth as f64)),
            ("npu_workers", Json::num(self.npu_workers as f64)),
            ("cpu_workers", Json::num(self.cpu_workers as f64)),
            ("listen", Json::str(self.listen.clone())),
            ("pin_cpu_cores", Json::num(self.pin_cpu_cores as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip_json() {
        let c = Config::default();
        let j = c.to_json();
        let c2 = Config::from_json(&j);
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.npu_depth, c.npu_depth);
        assert_eq!(c2.slo_seconds, c.slo_seconds);
        assert_eq!(c2.hetero, c.hetero);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = json::parse(r#"{"model":"jina_micro","cpu_depth":3}"#).unwrap();
        let c = Config::from_json(&j);
        assert_eq!(c.model, "jina_micro");
        assert_eq!(c.cpu_depth, 3);
        assert_eq!(c.npu_depth, Config::default().npu_depth);
    }

    #[test]
    fn cli_overrides_win() {
        let args = Args::parse(
            ["x", "--model", "jina_micro", "--slo", "2.0", "--no-hetero"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = Config::default().apply_args(&args);
        assert_eq!(c.model, "jina_micro");
        assert_eq!(c.slo_seconds, 2.0);
        assert!(!c.hetero);
    }
}
