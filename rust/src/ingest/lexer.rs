//! Zero-copy incremental JSON lexing (hifijson-style).
//!
//! One [`Lexer`] abstraction, two sources:
//!
//! * [`SliceLexer`] — lexes a complete byte slice. Strings that contain
//!   no escapes are **borrowed** straight out of the input
//!   (`Cow::Borrowed`), and number tokens are returned as sub-slices, so
//!   lexing a document allocates only for values that genuinely need
//!   unescaping.
//! * [`ChunkLexer`] — lexes a *stream of byte chunks* (e.g. an HTTP
//!   chunked request body) without ever concatenating them: only the
//!   current chunk is resident, and a token that crosses a chunk seam —
//!   a split escape sequence, a split UTF-8 character, a number cut in
//!   half — is re-assembled byte-by-byte into the token's own buffer.
//!   Peak residency is therefore one chunk plus one in-flight token,
//!   never the whole body ([`ChunkLexer::peak_chunk_bytes`]).
//!
//! Number tokens preserve their source text (`"1e-7"` stays `"1e-7"`),
//! so downstream consumers choose their own numeric interpretation
//! (u64 ids parse exactly; scores go through `f64` like
//! [`crate::util::json`] does).
//!
//! The token grammar and escape handling deliberately match
//! [`crate::util::json::parse`] on every *valid* JSON document — the
//! property tests in `rust/tests/proptests.rs` hold the two parsers
//! equal over generated documents and adversarial chunk splits.

use std::borrow::Cow;
use std::fmt;

/// Lex/parse failure with the absolute byte offset it was detected at.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for LexError {}

/// A byte source the JSON parser can lex incrementally.
///
/// `Str`/`Num` are the payload types tokens carry: borrowed for
/// [`SliceLexer`], owned for [`ChunkLexer`].
pub trait Lexer {
    /// String token payload (borrowed from the input when possible).
    type Str: AsRef<str>;
    /// Number token payload — the source text, preserved verbatim.
    type Num: AsRef<str>;

    /// Current byte without consuming it; `None` at end of input.
    fn peek(&mut self) -> Option<u8>;
    /// Consume the byte last returned by [`Lexer::peek`].
    fn bump(&mut self);
    /// Absolute offset of the next unread byte (for error reporting).
    fn offset(&self) -> usize;

    /// Lex one string token (the cursor is on the opening quote).
    fn lex_string(&mut self) -> Result<Self::Str, LexError>;
    /// Lex one number token (the cursor is on `-` or a digit).
    fn lex_number(&mut self) -> Result<Self::Num, LexError>;

    fn err(&self, msg: &str) -> LexError {
        LexError { offset: self.offset(), msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.bump();
        }
    }

    /// Consume the literal `lit` (`null` / `true` / `false`).
    fn expect_lit(&mut self, lit: &'static str) -> Result<(), LexError> {
        for &b in lit.as_bytes() {
            if self.peek() != Some(b) {
                return Err(self.err(&format!("expected '{lit}'")));
            }
            self.bump();
        }
        Ok(())
    }
}

/// Width of a UTF-8 sequence from its lead byte; `None` for invalid
/// lead bytes (continuation bytes, overlong markers).
fn utf8_width(b: u8) -> Option<usize> {
    match b {
        0xC2..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF4 => Some(4),
        _ => None,
    }
}

/// Decode four hex digits (the payload of a `\u` escape).
fn hex4<L: Lexer + ?Sized>(lx: &mut L) -> Result<u32, LexError> {
    let mut v = 0u32;
    for _ in 0..4 {
        let c = lx.peek().ok_or_else(|| lx.err("truncated \\u escape"))?;
        lx.bump();
        let d = (c as char)
            .to_digit(16)
            .ok_or_else(|| lx.err("bad hex digit in \\u escape"))?;
        v = v * 16 + d;
    }
    Ok(v)
}

/// Decode string content from the cursor through the closing quote into
/// `out`, one byte at a time — escape sequences and multi-byte UTF-8
/// characters may arrive split across chunk seams; byte-wise decoding
/// through [`Lexer::peek`]/[`Lexer::bump`] re-assembles them without the
/// caller ever buffering more than the token itself. The opening quote
/// (and any escape-free prefix a fast path already copied) must have
/// been consumed.
fn decode_string_rest<L: Lexer + ?Sized>(lx: &mut L, out: &mut String) -> Result<(), LexError> {
    loop {
        let b = lx.peek().ok_or_else(|| lx.err("unterminated string"))?;
        lx.bump();
        match b {
            b'"' => return Ok(()),
            b'\\' => {
                let e = lx.peek().ok_or_else(|| lx.err("truncated escape"))?;
                lx.bump();
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = hex4(lx)?;
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: a low surrogate must follow.
                            if lx.peek() != Some(b'\\') {
                                return Err(lx.err("expected low surrogate"));
                            }
                            lx.bump();
                            if lx.peek() != Some(b'u') {
                                return Err(lx.err("expected low surrogate"));
                            }
                            lx.bump();
                            let lo = hex4(lx)?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(lx.err("invalid low surrogate"));
                            }
                            char::from_u32(0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00))
                        } else {
                            char::from_u32(hi)
                        };
                        out.push(c.ok_or_else(|| lx.err("invalid codepoint"))?);
                    }
                    _ => return Err(lx.err("bad escape")),
                }
            }
            b if b < 0x20 => return Err(lx.err("control character in string")),
            b if b < 0x80 => out.push(b as char),
            b => {
                let width = utf8_width(b).ok_or_else(|| lx.err("invalid utf-8"))?;
                let mut bytes = [b, 0, 0, 0];
                for slot in bytes.iter_mut().take(width).skip(1) {
                    let c = lx.peek().ok_or_else(|| lx.err("truncated utf-8"))?;
                    lx.bump();
                    *slot = c;
                }
                let s = std::str::from_utf8(&bytes[..width])
                    .map_err(|_| lx.err("invalid utf-8"))?;
                out.push_str(s);
            }
        }
    }
}

/// Shared number grammar: `-?int(.frac)?([eE][+-]?exp)?` with at least
/// one digit in every digit run. `sink` receives each accepted byte.
fn scan_number<L, F>(lx: &mut L, mut sink: F) -> Result<(), LexError>
where
    L: Lexer + ?Sized,
    F: FnMut(u8),
{
    if lx.peek() == Some(b'-') {
        sink(b'-');
        lx.bump();
    }
    let mut int_digits = 0usize;
    while let Some(c) = lx.peek() {
        if !c.is_ascii_digit() {
            break;
        }
        sink(c);
        lx.bump();
        int_digits += 1;
    }
    if int_digits == 0 {
        return Err(lx.err("bad number"));
    }
    if lx.peek() == Some(b'.') {
        sink(b'.');
        lx.bump();
        let mut frac = 0usize;
        while let Some(c) = lx.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            sink(c);
            lx.bump();
            frac += 1;
        }
        if frac == 0 {
            return Err(lx.err("bad number: missing fraction digits"));
        }
    }
    if matches!(lx.peek(), Some(b'e' | b'E')) {
        sink(lx.peek().unwrap());
        lx.bump();
        if matches!(lx.peek(), Some(b'+' | b'-')) {
            sink(lx.peek().unwrap());
            lx.bump();
        }
        let mut exp = 0usize;
        while let Some(c) = lx.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            sink(c);
            lx.bump();
            exp += 1;
        }
        if exp == 0 {
            return Err(lx.err("bad number: missing exponent digits"));
        }
    }
    Ok(())
}

/// Zero-copy lexer over a complete byte slice.
pub struct SliceLexer<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> SliceLexer<'a> {
    pub fn new(bytes: &'a [u8]) -> SliceLexer<'a> {
        SliceLexer { bytes, pos: 0 }
    }
}

impl<'a> Lexer for SliceLexer<'a> {
    type Str = Cow<'a, str>;
    type Num = &'a str;

    fn peek(&mut self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) {
        self.pos += 1;
    }

    fn offset(&self) -> usize {
        self.pos
    }

    fn lex_string(&mut self) -> Result<Cow<'a, str>, LexError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.pos += 1;
        let start = self.pos;
        // Fast path: no escapes ⇒ borrow the content verbatim.
        loop {
            match self.bytes.get(self.pos) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    self.pos += 1;
                    return Ok(Cow::Borrowed(s));
                }
                Some(b'\\') => break,
                Some(&c) if c < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(_) => self.pos += 1,
            }
        }
        // Slow path: copy the escape-free prefix, then decode the rest.
        let mut s = String::with_capacity(self.pos - start + 16);
        s.push_str(
            std::str::from_utf8(&self.bytes[start..self.pos])
                .map_err(|_| self.err("invalid utf-8"))?,
        );
        decode_string_rest(self, &mut s)?;
        Ok(Cow::Owned(s))
    }

    fn lex_number(&mut self) -> Result<&'a str, LexError> {
        let start = self.pos;
        scan_number(self, |_| {})?;
        // The accepted grammar is pure ASCII.
        Ok(std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number"))
    }
}

/// Incremental lexer over a fallible chunk stream.
///
/// Holds exactly one chunk at a time; a token spanning a seam is
/// re-assembled into its own (token-sized) buffer. An I/O error from the
/// stream reads as end-of-input and is latched in
/// [`ChunkLexer::io_error`] so callers can distinguish a truncated
/// stream from a clean one.
pub struct ChunkLexer<I> {
    chunks: I,
    cur: Vec<u8>,
    pos: usize,
    consumed: usize,
    peak_chunk: usize,
    io_error: Option<String>,
}

impl<I> ChunkLexer<I>
where
    I: Iterator<Item = std::io::Result<Vec<u8>>>,
{
    pub fn new(chunks: I) -> ChunkLexer<I> {
        ChunkLexer {
            chunks,
            cur: Vec::new(),
            pos: 0,
            consumed: 0,
            peak_chunk: 0,
            io_error: None,
        }
    }

    /// Largest single chunk the stream has delivered — together with the
    /// in-flight token this bounds the lexer's peak residency (the
    /// "never materialize the body" guarantee: previous chunks are
    /// dropped as soon as the cursor leaves them).
    pub fn peak_chunk_bytes(&self) -> usize {
        self.peak_chunk
    }

    /// The stream error that ended the input, if any. While set, the
    /// lexer reports end-of-input.
    pub fn io_error(&self) -> Option<&str> {
        self.io_error.as_deref()
    }

    fn refill(&mut self) -> bool {
        if self.io_error.is_some() {
            return false;
        }
        while self.pos >= self.cur.len() {
            match self.chunks.next() {
                None => return false,
                Some(Err(e)) => {
                    self.io_error = Some(e.to_string());
                    return false;
                }
                Some(Ok(c)) => {
                    self.consumed += self.cur.len();
                    self.peak_chunk = self.peak_chunk.max(c.len());
                    self.cur = c;
                    self.pos = 0;
                }
            }
        }
        true
    }
}

impl<I> Lexer for ChunkLexer<I>
where
    I: Iterator<Item = std::io::Result<Vec<u8>>>,
{
    type Str = String;
    type Num = String;

    fn peek(&mut self) -> Option<u8> {
        if !self.refill() {
            return None;
        }
        Some(self.cur[self.pos])
    }

    fn bump(&mut self) {
        self.pos += 1;
    }

    fn offset(&self) -> usize {
        self.consumed + self.pos
    }

    fn lex_string(&mut self) -> Result<String, LexError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.bump();
        let mut s = String::new();
        decode_string_rest(self, &mut s)?;
        Ok(s)
    }

    fn lex_number(&mut self) -> Result<String, LexError> {
        let mut text = String::new();
        // scan_number only feeds ASCII bytes, so the char cast is exact.
        scan_number(self, |b| text.push(b as char))?;
        Ok(text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type VecChunks = ChunkLexer<std::vec::IntoIter<std::io::Result<Vec<u8>>>>;

    fn chunked(bytes: &[u8], at: &[usize]) -> VecChunks {
        let mut chunks: Vec<std::io::Result<Vec<u8>>> = Vec::new();
        let mut prev = 0;
        for &p in at {
            chunks.push(Ok(bytes[prev..p].to_vec()));
            prev = p;
        }
        chunks.push(Ok(bytes[prev..].to_vec()));
        ChunkLexer::new(chunks.into_iter())
    }

    #[test]
    fn slice_lexer_borrows_unescaped_strings() {
        let mut lx = SliceLexer::new(br#""plain text""#);
        match lx.lex_string().unwrap() {
            Cow::Borrowed(s) => assert_eq!(s, "plain text"),
            Cow::Owned(_) => panic!("escape-free string must borrow"),
        }
    }

    #[test]
    fn slice_lexer_unescapes_when_needed() {
        let src = r#""a\nbAé😀""#;
        let mut lx = SliceLexer::new(src.as_bytes());
        match lx.lex_string().unwrap() {
            Cow::Owned(s) => assert_eq!(s, "a\nbAé😀"),
            Cow::Borrowed(_) => panic!("escaped string must own"),
        }
    }

    #[test]
    fn number_text_is_preserved() {
        for t in ["0", "-0", "42", "-3.5e2", "1e-7", "123456789123456789", "5E+3"] {
            let mut lx = SliceLexer::new(t.as_bytes());
            assert_eq!(lx.lex_number().unwrap(), t);
        }
    }

    #[test]
    fn bad_numbers_rejected() {
        for t in ["-", ".5", "1.", "1e", "1e+", "--1"] {
            let mut lx = SliceLexer::new(t.as_bytes());
            assert!(lx.lex_number().is_err(), "{t:?} must be rejected");
        }
    }

    #[test]
    fn chunk_lexer_survives_every_seam_position() {
        // The canonical seam hazards: escape split, \u split, UTF-8
        // split, number split. Cut the input at EVERY position.
        let src = r#""a\néé" -12.5e-3 "日本""#.as_bytes();
        for cut in 1..src.len() {
            let mut lx = chunked(src, &[cut]);
            assert_eq!(lx.lex_string().unwrap(), "a\néé", "cut={cut}");
            lx.skip_ws();
            assert_eq!(lx.lex_number().unwrap(), "-12.5e-3", "cut={cut}");
            lx.skip_ws();
            assert_eq!(lx.lex_string().unwrap(), "日本", "cut={cut}");
            assert_eq!(lx.peek(), None);
            assert!(lx.io_error().is_none());
        }
    }

    #[test]
    fn chunk_lexer_latches_io_errors() {
        let chunks: Vec<std::io::Result<Vec<u8>>> = vec![
            Ok(b"\"ab".to_vec()),
            Err(std::io::Error::new(std::io::ErrorKind::Other, "reset")),
        ];
        let mut lx = ChunkLexer::new(chunks.into_iter());
        let err = lx.lex_string().unwrap_err();
        assert!(err.msg.contains("unterminated"), "{err}");
        assert!(lx.io_error().unwrap().contains("reset"));
    }

    #[test]
    fn chunk_lexer_peak_is_one_chunk() {
        // 10 chunks of ≤8 bytes: residency never exceeds one chunk.
        let src = br#""hello world, this is a long-ish string""#;
        let cuts: Vec<usize> = (1..src.len()).step_by(8).collect();
        let mut lx = chunked(src, &cuts);
        lx.lex_string().unwrap();
        assert!(lx.peak_chunk_bytes() <= 8, "{}", lx.peak_chunk_bytes());
    }

    #[test]
    fn literals_and_ws() {
        let mut lx = SliceLexer::new(b"  \t\r\n true");
        lx.skip_ws();
        lx.expect_lit("true").unwrap();
        assert_eq!(lx.peek(), None);
        let mut lx = SliceLexer::new(b"tru");
        assert!(lx.expect_lit("true").is_err());
    }

    #[test]
    fn lone_low_surrogate_rejected() {
        let mut lx = SliceLexer::new(br#""\udc00""#);
        assert!(lx.lex_string().is_err());
        let mut lx = SliceLexer::new(br#""\ud800x""#);
        assert!(lx.lex_string().is_err());
    }
}
