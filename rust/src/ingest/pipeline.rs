//! The streaming ingestion pipeline: NDJSON chunks → zero-copy parse →
//! admission-controlled embedding (`WorkClass::Ingest`) → batched
//! commits into the live retrieval index.
//!
//! Flow per document (bounded memory, bounded CPU):
//!
//! 1. [`super::ndjson::DocStream`] over a [`super::lexer::ChunkLexer`]
//!    parses the upload chunk-by-chunk — peak parse residency is one
//!    chunk plus the document under the cursor, never the body.
//! 2. Each document's text is submitted through
//!    `WindVE::submit_ingest`, which admits it under the strictly-capped
//!    `WorkClass::Ingest` (NPU valley first, CPU overflow second). BUSY
//!    is *backpressure*, not failure: the pipeline sleeps and retries,
//!    which stalls the upload socket and slows the client — admission
//!    control propagated all the way to the producer.
//! 3. Embedded documents accumulate into a commit batch. When the
//!    service has a [`crate::durability::DurableStore`] attached, the
//!    batch is WAL-logged and fsynced *before* the index commit — the
//!    ack ⇒ WAL-durable half of the durability contract; a WAL failure
//!    refuses the whole batch (counted failed, never acked). The commit
//!    itself is `RetrievalExecutor::upsert_batch`: re-uploading an id
//!    replaces its row (tombstone + append) under one write lock, and
//!    the corpus version advances once per batch so NPU mirrors
//!    invalidate and concurrent scans see at most one barrier per
//!    commit. After each commit the store may trigger a tombstone
//!    compaction (see `DurableStore::maybe_compact`).
//!
//! A stream-level failure (socket died, malformed JSON) ends the stream
//! but keeps everything already committed — ingestion is at-least-once
//! per document, idempotent per id (re-upload = upsert).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::service::{ServeError, WindVE};
use crate::util::json::Json;

use super::ndjson::{docs_from_chunks, Doc, DocError};

/// Tuning for one ingest stream.
#[derive(Debug, Clone)]
pub struct IngestOptions {
    /// Documents per index commit (one write lock + one version window
    /// per batch).
    pub commit_batch: usize,
    /// First sleep when admission answers BUSY; each consecutive BUSY
    /// for the same document doubles the sleep (capped by
    /// [`IngestOptions::busy_backoff_cap`]), so a saturated ingest class
    /// costs O(log) wakeups instead of a 2ms polling spin.
    pub busy_backoff: Duration,
    /// Ceiling for the exponential backoff sleep.
    pub busy_backoff_cap: Duration,
    /// Per-document budget covering admission retries + embedding; a doc
    /// that cannot make it through in time is counted failed and the
    /// stream moves on.
    pub doc_timeout: Duration,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions {
            commit_batch: 32,
            busy_backoff: Duration::from_millis(2),
            busy_backoff_cap: Duration::from_millis(256),
            doc_timeout: Duration::from_secs(30),
        }
    }
}

impl IngestOptions {
    /// Backoff sleep before retry number `attempt` (0-based) of one
    /// document's admission: `busy_backoff · 2^attempt`, capped.
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let base = self.busy_backoff.max(Duration::from_micros(1));
        let exp = base.saturating_mul(1u32 << attempt.min(16));
        exp.min(self.busy_backoff_cap.max(base))
    }
}

/// Service-lifetime ingest counters (all streams), surfaced by
/// `GET /v1/ingest/status`.
#[derive(Debug, Default)]
pub struct IngestStats {
    received: AtomicU64,
    indexed: AtomicU64,
    failed: AtomicU64,
    busy_waits: AtomicU64,
    peak_doc_retries: AtomicU64,
    wal_refused: AtomicU64,
    batches: AtomicU64,
    streams: AtomicU64,
    active_streams: AtomicU64,
    peak_chunk_bytes: AtomicUsize,
}

impl IngestStats {
    /// Fold a finished stream's outcome into the service-wide counters.
    fn absorb(&self, o: &IngestOutcome) {
        self.received.fetch_add(o.received, Ordering::Relaxed);
        self.indexed.fetch_add(o.indexed, Ordering::Relaxed);
        self.failed.fetch_add(o.failed, Ordering::Relaxed);
        self.busy_waits.fetch_add(o.busy_waits, Ordering::Relaxed);
        self.peak_doc_retries.fetch_max(o.peak_doc_retries, Ordering::Relaxed);
        self.wal_refused.fetch_add(o.wal_refused, Ordering::Relaxed);
        self.batches.fetch_add(o.batches, Ordering::Relaxed);
        self.streams.fetch_add(1, Ordering::Relaxed);
        self.peak_chunk_bytes.fetch_max(o.peak_chunk_bytes, Ordering::Relaxed);
    }

    /// Point-in-time JSON snapshot (plus the caller-supplied live corpus
    /// version so operators can reconcile indexed counts against it).
    pub fn to_json(&self, corpus_version: Option<u64>) -> Json {
        Json::obj(vec![
            ("docs_received", Json::num(self.received.load(Ordering::Relaxed) as f64)),
            ("docs_indexed", Json::num(self.indexed.load(Ordering::Relaxed) as f64)),
            ("docs_failed", Json::num(self.failed.load(Ordering::Relaxed) as f64)),
            ("busy_waits", Json::num(self.busy_waits.load(Ordering::Relaxed) as f64)),
            (
                "peak_doc_retries",
                Json::num(self.peak_doc_retries.load(Ordering::Relaxed) as f64),
            ),
            ("wal_refused", Json::num(self.wal_refused.load(Ordering::Relaxed) as f64)),
            ("batches_committed", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("streams_completed", Json::num(self.streams.load(Ordering::Relaxed) as f64)),
            (
                "active_streams",
                Json::num(self.active_streams.load(Ordering::Relaxed) as f64),
            ),
            (
                "peak_chunk_bytes",
                Json::num(self.peak_chunk_bytes.load(Ordering::Relaxed) as f64),
            ),
            (
                "corpus_version",
                match corpus_version {
                    Some(v) => Json::num(v as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn docs_indexed(&self) -> u64 {
        self.indexed.load(Ordering::Relaxed)
    }

    pub fn docs_received(&self) -> u64 {
        self.received.load(Ordering::Relaxed)
    }
}

/// What one ingest stream accomplished.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IngestOutcome {
    /// Documents parsed off the stream (incl. ones that later failed).
    pub received: u64,
    /// Documents embedded and committed into the live index.
    pub indexed: u64,
    /// Documents dropped: bad shape, embed failure, or timeout.
    pub failed: u64,
    /// Admission BUSY retries absorbed (backpressure events).
    pub busy_waits: u64,
    /// Worst single document's BUSY retry count (how deep the
    /// exponential backoff had to go).
    pub peak_doc_retries: u64,
    /// Documents embedded but never acked because the write-ahead log
    /// refused the batch (fsync/append failure): counted in `failed`.
    pub wal_refused: u64,
    /// Index commits performed.
    pub batches: u64,
    /// Corpus version after the final commit.
    pub corpus_version: u64,
    /// Largest chunk the parser ever held (one-chunk residency proof).
    pub peak_chunk_bytes: usize,
    /// Stream-level error that ended ingestion early (parse error, dead
    /// socket, no index attached); per-doc failures are only counted.
    pub error: Option<String>,
}

impl IngestOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("received", Json::num(self.received as f64)),
            ("indexed", Json::num(self.indexed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("busy_waits", Json::num(self.busy_waits as f64)),
            ("peak_doc_retries", Json::num(self.peak_doc_retries as f64)),
            ("wal_refused", Json::num(self.wal_refused as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("corpus_version", Json::num(self.corpus_version as f64)),
            ("peak_chunk_bytes", Json::num(self.peak_chunk_bytes as f64)),
            (
                "error",
                match &self.error {
                    Some(e) => Json::str(e.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Ingest an NDJSON chunk stream into `svc`'s attached retrieval index.
///
/// Blocking: runs on the caller's thread (for the HTTP front end that is
/// the connection handler, so admission backpressure stalls the upload
/// socket instead of buffering). Returns when the stream is drained or a
/// stream-level error ends it.
pub fn ingest_ndjson_chunks<I>(svc: &WindVE, chunks: I, opts: &IngestOptions) -> IngestOutcome
where
    I: Iterator<Item = std::io::Result<Vec<u8>>>,
{
    let stats = svc.ingest_stats();
    stats.active_streams.fetch_add(1, Ordering::Relaxed);
    let outcome = run_stream(svc, chunks, opts);
    stats.absorb(&outcome);
    stats.active_streams.fetch_sub(1, Ordering::Relaxed);
    outcome
}

fn run_stream<I>(svc: &WindVE, chunks: I, opts: &IngestOptions) -> IngestOutcome
where
    I: Iterator<Item = std::io::Result<Vec<u8>>>,
{
    let mut out = IngestOutcome::default();
    let exec = match svc.retrieval() {
        Some(e) => e,
        None => {
            out.error = Some("no retrieval index attached to ingest into".into());
            return out;
        }
    };
    let commit_batch = opts.commit_batch.max(1);
    let mut stream = docs_from_chunks(chunks);
    let mut batch: Vec<Doc> = Vec::with_capacity(commit_batch);
    loop {
        let next = stream.next();
        match next {
            Some(Ok(doc)) => {
                out.received += 1;
                batch.push(doc);
                if batch.len() >= commit_batch {
                    commit(svc, &exec, &mut batch, opts, &mut out);
                }
            }
            Some(Err(DocError::Shape(m))) => {
                out.received += 1;
                out.failed += 1;
                log::debug!("ingest: dropping document: {m}");
            }
            Some(Err(DocError::Parse(e))) => {
                out.error = Some(e.to_string());
                break;
            }
            None => {
                if let Some(io) = stream.lexer().io_error() {
                    out.error = Some(format!("stream error: {io}"));
                }
                break;
            }
        }
    }
    commit(svc, &exec, &mut batch, opts, &mut out);
    out.peak_chunk_bytes = stream.lexer().peak_chunk_bytes();
    out.corpus_version = exec.version();
    out
}

/// Embed one commit batch through ingest admission and append it to the
/// live index under a single write lock.
fn commit(
    svc: &WindVE,
    exec: &crate::devices::executor::RetrievalExecutor,
    batch: &mut Vec<Doc>,
    opts: &IngestOptions,
    out: &mut IngestOutcome,
) {
    if batch.is_empty() {
        return;
    }
    let dim = exec.dim();
    // Submit the whole batch before waiting: admitted documents embed
    // concurrently up to the ingest caps.
    let mut tickets = Vec::with_capacity(batch.len());
    for doc in batch.drain(..) {
        let deadline = Instant::now() + opts.doc_timeout;
        let mut attempt: u32 = 0;
        let ticket = loop {
            match svc.submit_ingest(Arc::clone(&doc.text)) {
                Ok(t) => break Some(t),
                Err(ServeError::Busy) => {
                    out.busy_waits += 1;
                    if Instant::now() >= deadline {
                        break None;
                    }
                    std::thread::sleep(opts.backoff_for(attempt));
                    attempt += 1;
                }
                Err(_) => break None,
            }
        };
        out.peak_doc_retries = out.peak_doc_retries.max(attempt as u64);
        tickets.push((doc, ticket));
    }
    let mut rows: Vec<(u64, Vec<f32>)> = Vec::with_capacity(tickets.len());
    let mut texts: Vec<(u64, Arc<str>)> = Vec::with_capacity(tickets.len());
    for (doc, ticket) in tickets {
        match ticket.map(|t| t.wait(opts.doc_timeout)) {
            Some(Ok(v)) if v.len() == dim => {
                texts.push((doc.id, Arc::clone(&doc.text)));
                rows.push((doc.id, v));
            }
            Some(Ok(v)) => {
                out.failed += 1;
                log::warn!(
                    "ingest: doc {} embedding dim {} != index dim {dim}; dropped",
                    doc.id,
                    v.len()
                );
            }
            _ => out.failed += 1,
        }
    }
    if rows.is_empty() {
        return;
    }
    // Durability seam: the batch must be WAL-durable before the index
    // commit that makes it visible (and thus before the stream can ack
    // it). A refused append drops the whole batch unacked — the client
    // sees it in `failed` and retries; nothing half-committed exists.
    match svc.durability() {
        Some(store) => {
            let logged: Vec<(u64, &str)> = texts.iter().map(|(id, t)| (*id, &**t)).collect();
            match store.log_upserts(&logged, || {
                exec.upsert_batch(&rows);
            }) {
                Ok(()) => {
                    out.indexed += rows.len() as u64;
                    out.batches += 1;
                }
                Err(e) => {
                    out.failed += rows.len() as u64;
                    out.wal_refused += rows.len() as u64;
                    log::warn!("ingest: WAL refused batch of {}: {e}", rows.len());
                    return;
                }
            }
            if let Err(e) = store.maybe_compact(exec) {
                log::warn!("ingest: post-commit compaction failed: {e}");
            }
        }
        None => {
            out.indexed += rows.len() as u64;
            out.batches += 1;
            exec.upsert_batch(&rows);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok_chunks(src: &str, step: usize) -> Vec<std::io::Result<Vec<u8>>> {
        src.as_bytes().chunks(step).map(|c| Ok(c.to_vec())).collect()
    }

    #[test]
    fn outcome_json_has_the_operator_fields() {
        let o = IngestOutcome {
            received: 3,
            indexed: 2,
            failed: 1,
            corpus_version: 7,
            ..IngestOutcome::default()
        };
        let j = o.to_json();
        assert_eq!(j.get("received").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("indexed").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("corpus_version").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("error").unwrap(), &Json::Null);
    }

    #[test]
    fn stats_absorb_and_snapshot() {
        let s = IngestStats::default();
        s.absorb(&IngestOutcome {
            received: 10,
            indexed: 9,
            failed: 1,
            busy_waits: 4,
            batches: 2,
            peak_chunk_bytes: 512,
            ..IngestOutcome::default()
        });
        s.absorb(&IngestOutcome { peak_chunk_bytes: 128, ..IngestOutcome::default() });
        let j = s.to_json(Some(9));
        assert_eq!(j.get("docs_received").unwrap().as_u64(), Some(10));
        assert_eq!(j.get("docs_indexed").unwrap().as_u64(), Some(9));
        assert_eq!(j.get("streams_completed").unwrap().as_u64(), Some(2));
        // fetch_max: the larger stream's chunk bound wins.
        assert_eq!(j.get("peak_chunk_bytes").unwrap().as_u64(), Some(512));
        assert_eq!(j.get("corpus_version").unwrap().as_u64(), Some(9));
    }

    // End-to-end pipeline tests (live service + live index) run in
    // coordinator::service tests and rust/tests/server_http.rs, where a
    // service with workers exists; here we only cover the stream-error
    // path that needs no service plumbing.
    #[test]
    fn chunk_helper_shapes_are_sane() {
        let chunks = ok_chunks("{\"id\":1,\"text\":\"a\"}\n", 5);
        assert!(chunks.len() > 1);
    }

    #[test]
    fn backoff_doubles_then_caps() {
        let opts = IngestOptions::default();
        assert_eq!(opts.backoff_for(0), Duration::from_millis(2));
        assert_eq!(opts.backoff_for(1), Duration::from_millis(4));
        assert_eq!(opts.backoff_for(3), Duration::from_millis(16));
        // Deep attempts saturate at the cap instead of overflowing.
        assert_eq!(opts.backoff_for(20), Duration::from_millis(256));
        // A zero base never sleeps forever-zero: it is floored at 1µs.
        let z = IngestOptions { busy_backoff: Duration::ZERO, ..IngestOptions::default() };
        assert!(z.backoff_for(0) >= Duration::from_micros(1));
    }
}
