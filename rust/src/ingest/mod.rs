//! Streaming corpus ingestion — online indexing without stealing the
//! calibrated serving depth.
//!
//! The paper's deployment-cost model (Eqs. 9-10) prices a node by the
//! concurrency its calibrated queue depths can hold; before this
//! subsystem the repo could only *serve* a pre-built corpus, so every
//! corpus change meant an offline rebuild, and a naive bulk-upload
//! endpoint would have competed with latency-sensitive embed/retrieve
//! traffic for exactly that depth. `ingest` is the missing first-class
//! path:
//!
//! * [`lexer`] — zero-copy incremental JSON lexing over byte slices
//!   (borrowing) and chunked byte streams (one-chunk residency), escape
//!   and UTF-8 sequences intact across chunk seams.
//! * [`ndjson`] — a lexer-generic parser ([`ndjson::parse_value`],
//!   agreement with `util::json::parse` is property-tested) and the
//!   NDJSON [`ndjson::DocStream`] of `{"id", "text"}` documents.
//! * [`pipeline`] — parse → embed under the strictly-capped
//!   `WorkClass::Ingest` (NPU valley soak first, CPU overflow second,
//!   BUSY = exponential-backoff backpressure to the upload socket) →
//!   batched `RetrievalExecutor::upsert_batch` commits, WAL-logged
//!   before the ack when a `durability::DurableStore` is attached, that
//!   bump the corpus version so NPU mirrors invalidate.
//!
//! HTTP surface (see `crate::server`): `POST /v1/corpus` streams an
//! NDJSON body (chunked transfer-encoding supported) through the
//! pipeline; `GET /v1/ingest/status` reports the counters.

pub mod lexer;
pub mod ndjson;
pub mod pipeline;

pub use lexer::{ChunkLexer, LexError, Lexer, SliceLexer};
pub use ndjson::{docs_from_chunks, parse_slice, parse_value, Doc, DocStream, Value};
pub use pipeline::{ingest_ndjson_chunks, IngestOptions, IngestOutcome, IngestStats};
