//! Streaming NDJSON parsing over the incremental lexers.
//!
//! [`parse_value`] is a recursive-descent JSON parser generic over any
//! [`Lexer`], producing a [`Value`] whose string/number payloads are the
//! lexer's own token types: borrowed (`Cow`/`&str`) for [`SliceLexer`],
//! owned for [`ChunkLexer`]. On every valid document it agrees with
//! [`crate::util::json::parse`] — the [`Value::to_json`] bridge plus the
//! property tests in `rust/tests/proptests.rs` pin that equivalence.
//!
//! [`DocStream`] turns a lexer into an iterator of corpus documents
//! (`{"id": ..., "text": "..."}` per NDJSON line). Combined with a
//! [`ChunkLexer`] over an HTTP body, an upload of any size parses with
//! peak residency of one chunk plus one document.

use std::borrow::Cow;
use std::sync::Arc;

use super::lexer::{ChunkLexer, LexError, Lexer, SliceLexer};
use crate::util::json::Json;

/// Nesting bound: a hostile document must not overflow the parse stack.
const MAX_DEPTH: usize = 128;

/// A parsed JSON value, generic over string (`S`) and number-text (`N`)
/// payloads. Number text is preserved verbatim; convert at the edge.
#[derive(Debug, Clone, PartialEq)]
pub enum Value<S, N> {
    Null,
    Bool(bool),
    Num(N),
    Str(S),
    Arr(Vec<Value<S, N>>),
    Obj(Vec<(S, Value<S, N>)>),
}

/// The zero-copy flavor: unescaped strings borrow from the input slice.
pub type SliceValue<'a> = Value<Cow<'a, str>, &'a str>;
/// The chunked flavor: payloads own their bytes.
pub type OwnedValue = Value<String, String>;

impl<S: AsRef<str>, N: AsRef<str>> Value<S, N> {
    pub fn get(&self, key: &str) -> Option<&Value<S, N>> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k.as_ref() == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_ref()),
            _ => None,
        }
    }

    /// Number as f64 (via the preserved text, exactly like
    /// `util::json::parse` converts).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => n.as_ref().parse().ok(),
            _ => None,
        }
    }

    /// Number as u64 — **exact** for integer text (no f64 round-trip, so
    /// ids above 2^53 survive). Scientific/decimal notation is accepted
    /// only when it denotes an exact, in-range, non-negative integer
    /// (`1e3` → 1000); anything else is `None` rather than a silently
    /// saturated/truncated cast — a negative or fractional ingest id
    /// must be rejected, not remapped onto someone else's document.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) => n.as_ref().parse::<u64>().ok().or_else(|| {
                let f = n.as_ref().parse::<f64>().ok()?;
                // Exclusive upper bound: u64::MAX rounds UP to 2^64 as
                // f64, which would saturate-cast back to u64::MAX and
                // alias unrelated huge inputs onto one id.
                if f >= 0.0 && f < u64::MAX as f64 && f.fract() == 0.0 {
                    Some(f as u64)
                } else {
                    None
                }
            }),
            _ => None,
        }
    }

    /// Bridge into the in-repo DOM ([`crate::util::json::Json`]): the
    /// value `util::json::parse` would have produced for the same text.
    pub fn to_json(&self) -> Json {
        match self {
            Value::Null => Json::Null,
            Value::Bool(b) => Json::Bool(*b),
            Value::Num(n) => Json::Num(n.as_ref().parse().unwrap_or(f64::NAN)),
            Value::Str(s) => Json::Str(s.as_ref().to_string()),
            Value::Arr(items) => Json::Arr(items.iter().map(Value::to_json).collect()),
            Value::Obj(kvs) => Json::Obj(
                kvs.iter()
                    .map(|(k, v)| (k.as_ref().to_string(), v.to_json()))
                    .collect(),
            ),
        }
    }
}

/// Parse one JSON value starting at the lexer's cursor (leading
/// whitespace allowed; trailing input is left unconsumed).
pub fn parse_value<L: Lexer>(lx: &mut L) -> Result<Value<L::Str, L::Num>, LexError> {
    value_at_depth(lx, 0)
}

fn value_at_depth<L: Lexer>(
    lx: &mut L,
    depth: usize,
) -> Result<Value<L::Str, L::Num>, LexError> {
    if depth > MAX_DEPTH {
        return Err(lx.err("nesting too deep"));
    }
    lx.skip_ws();
    match lx.peek() {
        None => Err(lx.err("unexpected end of input")),
        Some(b'n') => lx.expect_lit("null").map(|_| Value::Null),
        Some(b't') => lx.expect_lit("true").map(|_| Value::Bool(true)),
        Some(b'f') => lx.expect_lit("false").map(|_| Value::Bool(false)),
        Some(b'"') => lx.lex_string().map(Value::Str),
        Some(b'[') => {
            lx.bump();
            let mut items = Vec::new();
            lx.skip_ws();
            if lx.peek() == Some(b']') {
                lx.bump();
                return Ok(Value::Arr(items));
            }
            loop {
                items.push(value_at_depth(lx, depth + 1)?);
                lx.skip_ws();
                match lx.peek() {
                    Some(b',') => {
                        lx.bump();
                    }
                    Some(b']') => {
                        lx.bump();
                        return Ok(Value::Arr(items));
                    }
                    _ => return Err(lx.err("expected ',' or ']'")),
                }
            }
        }
        Some(b'{') => {
            lx.bump();
            let mut kvs = Vec::new();
            lx.skip_ws();
            if lx.peek() == Some(b'}') {
                lx.bump();
                return Ok(Value::Obj(kvs));
            }
            loop {
                lx.skip_ws();
                let key = lx.lex_string()?;
                lx.skip_ws();
                if lx.peek() != Some(b':') {
                    return Err(lx.err("expected ':'"));
                }
                lx.bump();
                let val = value_at_depth(lx, depth + 1)?;
                kvs.push((key, val));
                lx.skip_ws();
                match lx.peek() {
                    Some(b',') => {
                        lx.bump();
                    }
                    Some(b'}') => {
                        lx.bump();
                        return Ok(Value::Obj(kvs));
                    }
                    _ => return Err(lx.err("expected ',' or '}'")),
                }
            }
        }
        Some(c) if c == b'-' || c.is_ascii_digit() => lx.lex_number().map(Value::Num),
        Some(_) => Err(lx.err("unexpected character")),
    }
}

/// Parse a complete document from a byte slice, zero-copy (unescaped
/// strings borrow from `bytes`). Trailing whitespace is allowed;
/// trailing data is an error — the whole-document twin of
/// [`crate::util::json::parse`].
pub fn parse_slice(bytes: &[u8]) -> Result<SliceValue<'_>, LexError> {
    let mut lx = SliceLexer::new(bytes);
    let v = parse_value(&mut lx)?;
    lx.skip_ws();
    if lx.peek().is_some() {
        return Err(lx.err("trailing data"));
    }
    Ok(v)
}

/// One corpus document from an NDJSON ingest stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Doc {
    pub id: u64,
    /// Shared text payload: travels HTTP → queue → backend batch without
    /// another copy.
    pub text: Arc<str>,
}

/// Why one NDJSON line did not become a [`Doc`].
#[derive(Debug, Clone, PartialEq)]
pub enum DocError {
    /// Malformed JSON; the byte offset is absolute within the stream.
    /// The stream cannot reliably resync past unbalanced quotes, so
    /// parsing stops here.
    Parse(LexError),
    /// Valid JSON but not a `{"id": u64ish, "text": str}` document; the
    /// stream continues with the next line.
    Shape(String),
}

impl std::fmt::Display for DocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DocError::Parse(e) => write!(f, "{e}"),
            DocError::Shape(m) => write!(f, "bad document: {m}"),
        }
    }
}

/// Extract the ingest document shape from a parsed value.
fn doc_from_value<S: AsRef<str>, N: AsRef<str>>(v: &Value<S, N>) -> Result<Doc, DocError> {
    let id = match v.get("id") {
        Some(n @ Value::Num(_)) => n
            .as_u64()
            .ok_or_else(|| DocError::Shape("id is not a u64".into()))?,
        // Accept string ids of digits (a common NDJSON export shape).
        Some(Value::Str(s)) => s
            .as_ref()
            .parse::<u64>()
            .map_err(|_| DocError::Shape(format!("id {:?} is not a u64", s.as_ref())))?,
        Some(_) => return Err(DocError::Shape("id is not a number".into())),
        None => return Err(DocError::Shape("missing \"id\"".into())),
    };
    let text = match v.get("text") {
        Some(Value::Str(s)) => Arc::<str>::from(s.as_ref()),
        Some(_) => return Err(DocError::Shape("\"text\" is not a string".into())),
        None => return Err(DocError::Shape("missing \"text\"".into())),
    };
    Ok(Doc { id, text })
}

/// Streaming document reader: one `{"id", "text"}` object per NDJSON
/// line (blank lines and extra whitespace tolerated). Documents are
/// parsed and surrendered one at a time — the stream never holds more
/// than the document under the cursor.
pub struct DocStream<L> {
    lx: L,
    stopped: bool,
}

impl<L: Lexer> DocStream<L> {
    pub fn new(lx: L) -> DocStream<L> {
        DocStream { lx, stopped: false }
    }

    /// The underlying lexer (e.g. to read [`ChunkLexer::peak_chunk_bytes`]
    /// after the stream is drained).
    pub fn lexer(&self) -> &L {
        &self.lx
    }
}

impl<L: Lexer> Iterator for DocStream<L> {
    type Item = Result<Doc, DocError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.stopped {
            return None;
        }
        self.lx.skip_ws();
        self.lx.peek()?;
        match parse_value(&mut self.lx) {
            Err(e) => {
                // A JSON-level error leaves the cursor mid-token; there
                // is no safe resync point, so the stream ends here.
                self.stopped = true;
                Some(Err(DocError::Parse(e)))
            }
            Ok(v) => Some(doc_from_value(&v)),
        }
    }
}

/// Convenience: stream documents straight off a chunked byte source.
pub fn docs_from_chunks<I>(chunks: I) -> DocStream<ChunkLexer<I>>
where
    I: Iterator<Item = std::io::Result<Vec<u8>>>,
{
    DocStream::new(ChunkLexer::new(chunks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parses_like_util_json_on_a_nested_doc() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x","n":-3.5e2,"t":true}"#;
        let ours = parse_slice(src.as_bytes()).unwrap().to_json();
        let theirs = json::parse(src).unwrap();
        assert_eq!(ours, theirs);
    }

    #[test]
    fn zero_copy_borrows_plain_strings() {
        let src = r#"{"id": 7, "text": "no escapes here"}"#;
        let v = parse_slice(src.as_bytes()).unwrap();
        match v.get("text").unwrap() {
            Value::Str(Cow::Borrowed(s)) => assert_eq!(*s, "no escapes here"),
            other => panic!("expected borrowed text, got {other:?}"),
        }
    }

    #[test]
    fn number_text_survives_parsing() {
        let v = parse_slice(b"[1e-7, 18446744073709551615]").unwrap();
        match &v {
            Value::Arr(items) => {
                assert_eq!(items[0], Value::Num("1e-7"));
                // u64::MAX round-trips exactly — no f64 mangling.
                assert_eq!(items[1].as_u64(), Some(u64::MAX));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_what_util_json_rejects() {
        for src in ["{", "[1,]", "tru", "\"abc", "1 2", "{\"a\" 1}", ""] {
            assert!(parse_slice(src.as_bytes()).is_err(), "{src:?}");
            assert!(json::parse(src).is_err(), "{src:?}");
        }
    }

    #[test]
    fn depth_bound_rejects_hostile_nesting() {
        let hostile = "[".repeat(4096);
        assert!(parse_slice(hostile.as_bytes()).is_err());
    }

    /// Review regression: negative, fractional, or astronomically large
    /// ids must be rejected as bad documents — a saturating cast would
    /// silently commit them under someone else's id (e.g. -1 → 0).
    #[test]
    fn non_u64_ids_are_rejected_not_remapped() {
        for bad in ["-1", "2.7", "1e300", "-0.5"] {
            let line = format!("{{\"id\":{bad},\"text\":\"x\"}}");
            let mut s = DocStream::new(SliceLexer::new(line.as_bytes()));
            match s.next().unwrap() {
                Err(DocError::Shape(m)) => assert!(m.contains("u64"), "{bad}: {m}"),
                other => panic!("{bad}: expected shape error, got {other:?}"),
            }
        }
        // Exact-integer scientific/decimal notation is a legitimate id.
        for (text, want) in [("1e3", 1000u64), ("1.5e1", 15)] {
            let line = format!("{{\"id\":{text},\"text\":\"x\"}}");
            let mut s = DocStream::new(SliceLexer::new(line.as_bytes()));
            assert_eq!(s.next().unwrap().unwrap().id, want, "{text}");
        }
    }

    #[test]
    fn doc_stream_reads_ndjson_lines() {
        let src = "{\"id\":1,\"text\":\"alpha\"}\n{\"id\":\"2\",\"text\":\"beta\"}\n\n  {\"text\":\"no id\"}\n{\"id\":4,\"text\":\"delta\"}";
        let mut s = DocStream::new(SliceLexer::new(src.as_bytes()));
        assert_eq!(
            s.next().unwrap().unwrap(),
            Doc { id: 1, text: Arc::from("alpha") }
        );
        assert_eq!(s.next().unwrap().unwrap().id, 2);
        assert!(matches!(s.next().unwrap(), Err(DocError::Shape(_))));
        assert_eq!(s.next().unwrap().unwrap().id, 4);
        assert!(s.next().is_none());
    }

    #[test]
    fn doc_stream_stops_at_parse_errors() {
        let src = "{\"id\":1,\"text\":\"ok\"}\n{\"id\":2,\"text\":\"unterminated";
        let mut s = DocStream::new(SliceLexer::new(src.as_bytes()));
        assert!(s.next().unwrap().is_ok());
        assert!(matches!(s.next().unwrap(), Err(DocError::Parse(_))));
        assert!(s.next().is_none());
    }

    #[test]
    fn chunked_doc_stream_equals_slice_doc_stream() {
        let src = "{\"id\":1,\"text\":\"héllo\\nworld\"}\n{\"id\":2,\"text\":\"日本語テキスト\"}\n";
        let want: Vec<Doc> = DocStream::new(SliceLexer::new(src.as_bytes()))
            .map(|d| d.unwrap())
            .collect();
        let bytes = src.as_bytes();
        for step in 1..=7usize {
            let chunks: Vec<std::io::Result<Vec<u8>>> =
                bytes.chunks(step).map(|c| Ok(c.to_vec())).collect();
            let got: Vec<Doc> =
                docs_from_chunks(chunks.into_iter()).map(|d| d.unwrap()).collect();
            assert_eq!(got, want, "chunk step {step}");
        }
    }
}
