//! Bench target regenerating the paper's Table 1 (bge: WindVE vs
//! FlagEmbedding max concurrency) and asserting the expected shape:
//! offloading wins, looser SLO wins more, the small-gap pair wins most.

use windve::repro::{pct, table1};

fn main() {
    let seed = 42;
    let rows = table1::run(seed);
    table1::print(&rows, "Table 1 — bge model, WindVE vs FlagEmbedding", "FlagEmb");

    // Shape assertions (who wins, by roughly what factor).
    let mut failures = Vec::new();
    for r in &rows {
        let base_err =
            (r.baseline as f64 - r.paper_baseline as f64).abs() / r.paper_baseline as f64;
        if base_err > 0.10 {
            failures.push(format!(
                "{}@{}s baseline {} vs paper {}",
                r.npu_name, r.slo, r.baseline, r.paper_baseline
            ));
        }
        let paper_pct = pct(r.paper_baseline, r.paper_additional);
        if (r.improvement_pct - paper_pct).abs() > 8.0 {
            failures.push(format!(
                "{}@{}s improvement {:.1}% vs paper {:.1}%",
                r.npu_name, r.slo, r.improvement_pct, paper_pct
            ));
        }
    }
    if !(rows[1].improvement_pct > rows[0].improvement_pct) {
        failures.push("2s SLO should outgain 1s SLO (paper phenomenon 1)".into());
    }
    if !(rows[0].improvement_pct > rows[2].improvement_pct) {
        failures.push("V100+Xeon should outgain Atlas+Kunpeng (phenomenon 2)".into());
    }
    report(failures);
}

fn report(failures: Vec<String>) {
    if failures.is_empty() {
        println!("\nSHAPE OK — all paper phenomena reproduced");
    } else {
        for f in &failures {
            println!("SHAPE MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
