//! Bench target regenerating the paper's Table 2 (jina: WindVE vs
//! PyTorch). Faster model → larger offloading gains than Table 1.

use windve::repro::{pct, table1, table2};

fn main() {
    let seed = 42;
    let rows = table2::run(seed);
    table2::print(&rows);

    let bge = table1::run(seed);
    let mut failures = Vec::new();
    for r in &rows {
        let base_err =
            (r.baseline as f64 - r.paper_baseline as f64).abs() / r.paper_baseline as f64;
        if base_err > 0.10 {
            failures.push(format!(
                "{}@{}s baseline {} vs paper {}",
                r.npu_name, r.slo, r.baseline, r.paper_baseline
            ));
        }
        let paper_pct = pct(r.paper_baseline, r.paper_additional);
        if (r.improvement_pct - paper_pct).abs() > 8.0 {
            failures.push(format!(
                "{}@{}s improvement {:.1}% vs paper {:.1}%",
                r.npu_name, r.slo, r.improvement_pct, paper_pct
            ));
        }
    }
    // Paper phenomenon 3: jina (faster inference) gains more than bge.
    for (j, b) in rows.iter().zip(&bge) {
        if j.improvement_pct + 1.0 <= b.improvement_pct {
            failures.push(format!(
                "jina should outgain bge: {:.1}% vs {:.1}% ({}@{}s)",
                j.improvement_pct, b.improvement_pct, j.npu_name, j.slo
            ));
        }
    }
    if failures.is_empty() {
        println!("\nSHAPE OK — jina gains exceed bge gains as in the paper");
    } else {
        for f in &failures {
            println!("SHAPE MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
