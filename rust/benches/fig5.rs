//! Bench target regenerating Figure 5: concurrency vs query length.
//! Asserts the crossovers the paper reports: CPU additional concurrency
//! reaches 0 at 500 tokens under the 1 s SLO but survives (~2) under 2 s.

use windve::repro::fig5;

fn main() {
    let pts = fig5::run(42);
    fig5::print(&pts);

    let at = |slo: f64, qlen: usize| pts.iter().find(|p| p.slo == slo && p.qlen == qlen).unwrap();
    let mut failures = Vec::new();

    for &slo in &[1.0, 2.0] {
        let series: Vec<_> = pts.iter().filter(|p| p.slo == slo).collect();
        for w in series.windows(2) {
            if w[1].original > w[0].original || w[1].additional > w[0].additional {
                failures.push(format!("series not monotone at {} tokens/{}s", w[1].qlen, slo));
            }
        }
    }
    if at(1.0, 500).additional != 0 {
        failures.push(format!(
            "paper: additional→0 at 500tok/1s, got {}",
            at(1.0, 500).additional
        ));
    }
    let a2 = at(2.0, 500).additional;
    if !(1..=4).contains(&a2) {
        failures.push(format!("paper: ≈2 additional at 500tok/2s, got {a2}"));
    }
    if at(1.0, 75).original != 44 || at(1.0, 75).additional != 8 {
        failures.push("75-token anchor should match Table 1 (44+8)".into());
    }
    if failures.is_empty() {
        println!("\nSHAPE OK — Figure 5 length-scaling crossovers reproduced");
    } else {
        for f in &failures {
            println!("SHAPE MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
