//! Ablation studies for the design choices DESIGN.md calls out:
//! 1. Queue-depth sensitivity — why fine-tuning matters (mis-set depths
//!    either waste capacity or violate the SLO).
//! 2. OLS vs Theil-Sen on the outlier-heavy device (Kunpeng, §5.3).
//! 3. Embedding cache — repeats served without queue slots.
//! 4. Balancer policy — round-robin vs least-loaded under skew.

use windve::coordinator::balancer::{Balancer, Policy};
use windve::coordinator::cache::EmbeddingCache;
use windve::devices::profile::DeviceProfile;
use windve::estimator::robust::theil_sen;
use windve::estimator::LinearFit;
use windve::sim::cluster::ClosedLoopSim;
use windve::util::rng::Pcg;

fn main() {
    depth_sensitivity();
    estimator_ablation();
    cache_ablation();
    balancer_ablation();
    println!("\nablations OK");
}

/// 1: sweep the NPU depth around the fine-tuned 44 and report capacity
/// vs SLO violations — the asymmetric cost of mis-calibration.
fn depth_sensitivity() {
    println!("\n=== ablation 1: queue-depth sensitivity (V100, SLO 1s) ===");
    println!("{:>7} {:>12} {:>14}", "depth", "capacity", "SLO met@cap?");
    let npu = DeviceProfile::v100_bge();
    for delta in [-8i64, -4, 0, 4, 8] {
        let depth = (44i64 + delta) as usize;
        let mut sim = ClosedLoopSim::new(npu.clone(), None, depth, 0, 75, 1);
        sim.noisy = false;
        // Capacity is bounded by admission (depth) — but does a full batch
        // still meet the SLO?
        let r = sim.round(depth);
        println!(
            "{:>7} {:>12} {:>14}",
            depth,
            depth,
            if r.meets_slo(1.0) { "yes" } else { "VIOLATED" }
        );
        if depth < 44 {
            assert!(r.meets_slo(1.0), "under-depth must be safe");
        }
        if depth > 44 {
            assert!(!r.meets_slo(1.0), "over-depth must violate");
        }
    }
    println!("→ under-provisioning wastes capacity; over-provisioning breaks the SLO.");
}

/// 2: OLS vs Theil-Sen depth error on a Kunpeng-like outlier process.
fn estimator_ablation() {
    println!("\n=== ablation 2: OLS vs Theil-Sen on outlier-heavy probes (Kunpeng, 2s) ===");
    let dev = DeviceProfile::kunpeng_920_bge();
    let truth = dev.true_max_concurrency(2.0, 75);
    let mut ols_err = 0.0;
    let mut ts_err = 0.0;
    let trials = 40;
    for seed in 0..trials {
        let mut rng = Pcg::new(seed);
        let pts: Vec<(f64, f64)> = (1..=8)
            .map(|c| (c as f64, dev.noisy_service_time(c, 75, &mut rng)))
            .collect();
        let ols = LinearFit::fit(&pts).max_concurrency(2.0).min(64);
        let ts = theil_sen(&pts).max_concurrency(2.0).min(64);
        ols_err += (ols as f64 - truth as f64).abs();
        ts_err += (ts as f64 - truth as f64).abs();
    }
    ols_err /= trials as f64;
    ts_err /= trials as f64;
    println!("truth {truth}; mean |error|: OLS {ols_err:.2}, Theil-Sen {ts_err:.2} ({trials} trials)");
    assert!(
        ts_err <= ols_err + 0.5,
        "robust fit should not be worse on outlier device"
    );
}

/// 3: cache hit rate vs repeat fraction, and the equivalent capacity gain.
fn cache_ablation() {
    println!("\n=== ablation 3: embedding cache vs query repeat rate ===");
    println!("{:>9} {:>9} {:>22}", "repeat%", "hit%", "queue-slots saved/1k");
    for repeat_pct in [0u32, 20, 50, 80] {
        let cache = EmbeddingCache::new(512);
        let mut rng = Pcg::new(7);
        let mut saved = 0u32;
        for i in 0..1000u32 {
            let text = if rng.chance(repeat_pct as f64 / 100.0) && i > 0 {
                format!("repeat query {}", rng.range(0, 50))
            } else {
                format!("unique query {i}")
            };
            let key = EmbeddingCache::key(&text, 8192, 80);
            if cache.get(key).is_some() {
                saved += 1;
            } else {
                cache.put(key, vec![0.0; 8]);
            }
        }
        let (_, _, rate) = cache.stats();
        println!("{:>8}% {:>8.1}% {:>22}", repeat_pct, rate * 100.0, saved);
    }
    println!("→ every hit is a query served without an NPU/CPU queue slot.");
}

/// 4: round-robin vs least-loaded with one slow instance.
fn balancer_ablation() {
    println!("\n=== ablation 4: balancer policy with one degraded instance ===");
    for (name, policy) in [("round-robin", Policy::RoundRobin), ("least-loaded", Policy::LeastLoaded)] {
        let b = Balancer::new(4, policy);
        // Instance 0 completes at 1/4 the rate of the others.
        let mut stuck: Vec<usize> = Vec::new();
        let mut on_slow = 0usize;
        for step in 0..400 {
            let i = b.pick();
            if i == 0 {
                on_slow += 1;
                stuck.push(step);
                if stuck.len() >= 4 {
                    b.complete(0); // slow drain
                    stuck.pop();
                }
            } else {
                b.complete(i);
            }
        }
        println!("  {name:<13} sent {on_slow:>3}/400 queries to the degraded instance");
        if policy == Policy::LeastLoaded {
            assert!(on_slow < 150, "least-loaded should route around the slow instance");
        }
    }
}
