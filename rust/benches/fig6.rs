//! Bench target regenerating Figure 6: CPU additional concurrency vs
//! core count. Asserts the paper's benefit floors: no CPU gain below
//! ~44 cores at 1 s, below ~36 cores at 2 s.

use windve::repro::fig6;

fn main() {
    let pts = fig6::run(42);
    fig6::print(&pts);

    let at = |slo: f64, cores: usize| {
        pts.iter().find(|p| p.slo == slo && p.cores == cores).unwrap().additional
    };
    let mut failures = Vec::new();
    if at(1.0, 44) < 1 {
        failures.push("44 cores should still help at 1s".to_string());
    }
    if at(1.0, 36) != 0 {
        failures.push(format!("36 cores must not help at 1s (got {})", at(1.0, 36)));
    }
    if at(2.0, 36) < 1 {
        failures.push("36 cores should still help at 2s".to_string());
    }
    if at(2.0, 24) != 0 {
        failures.push(format!("24 cores must not help at 2s (got {})", at(2.0, 24)));
    }
    if at(1.0, 96) != 8 {
        failures.push(format!("96 cores @1s should give Table 1's 8 (got {})", at(1.0, 96)));
    }
    for &slo in &[1.0, 2.0] {
        let series: Vec<_> = pts.iter().filter(|p| p.slo == slo).collect();
        for w in series.windows(2) {
            if w[1].additional > w[0].additional {
                failures.push(format!("non-monotone at {} cores/{}s", w[1].cores, slo));
            }
        }
    }
    if failures.is_empty() {
        println!("\nSHAPE OK — Figure 6 core-count floors reproduced");
    } else {
        for f in &failures {
            println!("SHAPE MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
