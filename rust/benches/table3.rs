//! Bench target regenerating the paper's Table 3: queue-depth prediction
//! via linear regression vs stress test (step 8) vs fine-tuning, plus the
//! probe-economy claim that motivates the estimator.

use windve::devices::profile::DeviceProfile;
use windve::repro::table3;

fn main() {
    let rows = table3::run(42);
    table3::print(&rows);

    let mut failures = Vec::new();
    for r in &rows {
        let truth = DeviceProfile::by_name(&r.device)
            .expect("profile")
            .true_max_concurrency(r.slo, 75);
        // Fine-tuning must land on the device's true capacity.
        if r.fine_tuned != truth {
            failures.push(format!(
                "{}@{}s fine-tuned {} != truth {truth}",
                r.device, r.slo, r.fine_tuned
            ));
        }
        // Stress results quantise to the step (the paper's observed
        // weakness of large increments).
        if !(r.stress_test == 0 || r.stress_test == 1 || r.stress_test % 8 == 0) {
            failures.push(format!("stress {} not step-quantised", r.stress_test));
        }
        // Probe economy on large devices (the estimator's raison d'être).
        if truth > 90 && r.lr_probes >= r.stress_probes {
            failures.push(format!(
                "{}@{}s LR probes {} not cheaper than stress {}",
                r.device, r.slo, r.lr_probes, r.stress_probes
            ));
        }
    }
    if failures.is_empty() {
        println!("\nSHAPE OK — estimator comparable to stress at a fraction of the probes");
    } else {
        for f in &failures {
            println!("SHAPE MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
