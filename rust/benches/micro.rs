//! L3 hot-path microbenchmarks (the §Perf baseline): queue-manager
//! dispatch, batcher drain, tokenizer, histogram record, JSON encode,
//! cost model, linear fit, closed-loop sim round.

use std::sync::Arc;
use std::time::Instant;

use windve::benchkit::{bench, section};
use windve::coordinator::batcher::{DeviceQueue, Pending};
use windve::coordinator::queue_manager::{QueueManager, Route};
use windve::devices::profile::DeviceProfile;
use windve::estimator::LinearFit;
use windve::metrics::Histogram;
use windve::runtime::tokenizer;
use windve::sim::cluster::ClosedLoopSim;
use windve::util::json::{self, Json};
use windve::workload::queries::QueryGen;

fn main() {
    section("queue manager (Algorithm 1)");
    {
        let qm = QueueManager::new(44, 8, true);
        bench("dispatch+release (NPU fastpath)", || {
            let r = qm.dispatch();
            qm.release(r);
        })
        .report();

        let qm_full = QueueManager::new(0, 0, true);
        bench("dispatch (BUSY path)", || {
            let _ = qm_full.dispatch();
        })
        .report();

        // Contended: 4 threads hammering one queue manager.
        let qm = Arc::new(QueueManager::new(44, 8, true));
        let iters = 200_000u64;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let qm = Arc::clone(&qm);
                std::thread::spawn(move || {
                    for _ in 0..iters {
                        let r = qm.dispatch();
                        if r != Route::Busy {
                            qm.release(r);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ns = t0.elapsed().as_nanos() as f64 / (4 * iters) as f64;
        println!("{:<44} {:>12.1} ns/op   (4-thread contended)", "dispatch+release contended", ns);
    }

    section("device queue / batcher");
    {
        let q: DeviceQueue<u32> = DeviceQueue::new();
        bench("push+drain_batch(16)", || {
            for i in 0..16 {
                q.push(Pending {
                    text: String::new(),
                    enqueued: Instant::now(),
                    reply: i,
                });
            }
            let b = q.drain_batch(16).unwrap();
            std::hint::black_box(b.len());
        })
        .report();
    }

    section("tokenizer");
    {
        let mut gen = QueryGen::new(75, 1);
        let text = gen.query();
        bench("encode 75-token query (seq 80)", || {
            std::hint::black_box(tokenizer::encode(&text, 8192, 80));
        })
        .report();
        bench("token_count 75-token query", || {
            std::hint::black_box(tokenizer::token_count(&text));
        })
        .report();
    }

    section("metrics");
    {
        let h = Histogram::new();
        bench("histogram record", || h.record(123_456)).report();
        for i in 0..10_000 {
            h.record(i * 37);
        }
        bench("histogram p99", || {
            std::hint::black_box(h.quantile(0.99));
        })
        .report();
    }

    section("json");
    {
        let v = Json::obj(vec![
            ("texts", Json::Arr(vec![Json::str("hello world embedding query"); 8])),
            ("slo", Json::num(1.0)),
        ]);
        let s = v.to_string();
        bench("encode /v1/embed-ish body", || {
            std::hint::black_box(v.to_string());
        })
        .report();
        bench("parse /v1/embed-ish body", || {
            std::hint::black_box(json::parse(&s).unwrap());
        })
        .report();
    }

    section("estimator + sim (table regeneration cost)");
    {
        let pts: Vec<(f64, f64)> = (1..=32).map(|c| (c as f64, 0.0166 * c as f64 + 0.27)).collect();
        bench("OLS fit (32 points)", || {
            std::hint::black_box(LinearFit::fit(&pts));
        })
        .report();
        let mut sim = ClosedLoopSim::new(
            DeviceProfile::v100_bge(),
            Some(DeviceProfile::xeon_e5_2690_bge()),
            44,
            8,
            75,
            1,
        );
        bench("closed-loop sim round (52 clients)", || {
            std::hint::black_box(sim.round(52));
        })
        .report();
    }
}
