//! L3 hot-path microbenchmarks (the §Perf baseline): retrieval kernels,
//! queue-manager dispatch, batcher drain, tokenizer, histogram record,
//! JSON encode, cost model, linear fit, closed-loop sim round.

use std::sync::Arc;
use std::time::Instant;

use windve::benchkit::{bench, section};
use windve::coordinator::batcher::{DeviceQueue, Pending};
use windve::coordinator::queue_manager::{QueueManager, Route, WorkClass};
use windve::devices::profile::DeviceProfile;
use windve::estimator::LinearFit;
use windve::metrics::Histogram;
use windve::runtime::tokenizer;
use windve::sim::cluster::ClosedLoopSim;
use windve::util::json::{self, Json};
use windve::util::rng::Pcg;
use windve::vecstore::{kernels, quant, FlatIndex, Index, Quant};
use windve::workload::queries::QueryGen;

fn main() {
    section("vecstore kernels (dim 768)");
    {
        const DIM: usize = 768;
        const ROWS: usize = 1024;
        const NQ: usize = 8;
        let mut rng = Pcg::new(42);
        let mut randv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
        let rows = randv(ROWS * DIM);
        let queries = randv(NQ * DIM);
        let q0 = &queries[..DIM];
        println!("dispatched kernel: {}", kernels::name());

        bench("dot scalar (seed 4-lane)", || {
            std::hint::black_box(kernels::dot_scalar(q0, &rows[..DIM]));
        })
        .report();
        bench("dot dispatched (SIMD)", || {
            std::hint::black_box(kernels::dot(q0, &rows[..DIM]));
        })
        .report();

        // Full scans: ns/row so the three variants compare directly.
        let mut out1 = vec![0.0f32; ROWS];
        let scalar_scan = bench("scalar scan 1q x 1024 rows", || {
            for (r, o) in out1.iter_mut().enumerate() {
                *o = kernels::dot_scalar(q0, &rows[r * DIM..(r + 1) * DIM]);
            }
            std::hint::black_box(&out1);
        });
        scalar_scan.report();
        let simd_scan = bench("SIMD scan 1q x 1024 rows", || {
            kernels::scores_into(q0, &rows, ROWS, DIM, &mut out1);
            std::hint::black_box(&out1);
        });
        simd_scan.report();
        let mut out8 = vec![0.0f32; NQ * ROWS];
        let panel_scan = bench("SIMD panel 8q x 1024 rows", || {
            kernels::panel_scores_into(&queries, NQ, &rows, ROWS, DIM, &mut out8);
            std::hint::black_box(&out8);
        });
        panel_scan.report();

        // Quantized arenas: same panel shape, 2 B (f16) / 1 B (int8) per
        // row element across the memory bus, decode in registers.
        let rows_f16: Vec<u16> = rows.iter().map(|&x| quant::f32_to_f16(x)).collect();
        let mut rows_i8 = vec![0i8; ROWS * DIM];
        let mut scales = vec![0.0f32; ROWS];
        for r in 0..ROWS {
            let row = &rows[r * DIM..(r + 1) * DIM];
            scales[r] = quant::quantize_i8_row(row, &mut rows_i8[r * DIM..(r + 1) * DIM]);
        }
        bench("SIMD panel 8q x 1024 rows [f16]", || {
            kernels::panel_scores_f16_into(&queries, NQ, &rows_f16, ROWS, DIM, &mut out8);
            std::hint::black_box(&out8);
        })
        .report();
        bench("SIMD panel 8q x 1024 rows [int8]", || {
            kernels::panel_scores_i8_into(&queries, NQ, &rows_i8, &scales, ROWS, DIM, &mut out8);
            std::hint::black_box(&out8);
        })
        .report();

        // Product quantization: the scan reads 96 B (pq8) / 48 B (pq4)
        // per row instead of 3072, scored by m table lookups against the
        // per-panel ADC LUT (built once per panel — benched separately).
        for bits in [4u8, 8] {
            let Quant::Pq { m, .. } = Quant::pq(bits).resolved(DIM) else { unreachable!() };
            let book = Arc::new(windve::vecstore::pq::Codebook::train(
                &rows[..256 * DIM],
                DIM,
                m,
                bits,
                1,
            ));
            let mut codes = Vec::new();
            for r in 0..ROWS {
                book.encode_append(&rows[r * DIM..(r + 1) * DIM], &mut codes);
            }
            let lut = book.build_lut(&queries, NQ);
            bench(&format!("SIMD panel 8q x 1024 rows [pq{bits}]"), || {
                kernels::panel_scores_pq_into(
                    lut.table(),
                    NQ,
                    &codes,
                    ROWS,
                    m,
                    book.k(),
                    bits,
                    &mut out8,
                );
                std::hint::black_box(&out8);
            })
            .report();
            bench(&format!("adc lut build 8q [pq{bits}]"), || {
                std::hint::black_box(book.build_lut(&queries, NQ));
            })
            .report();
        }
        let per_pair_scalar = scalar_scan.mean_ns / ROWS as f64;
        let per_pair_simd = simd_scan.mean_ns / ROWS as f64;
        let per_pair_panel = panel_scan.mean_ns / (NQ * ROWS) as f64;
        println!(
            "{:<44} scalar {:.1} / simd {:.1} / batched {:.1} ns per (q,row): {:.1}x and {:.1}x",
            "per-pair speedup vs seed scalar",
            per_pair_scalar,
            per_pair_simd,
            per_pair_panel,
            per_pair_scalar / per_pair_simd,
            per_pair_scalar / per_pair_panel
        );
    }

    section("vecstore top-k + batched search");
    {
        let mut rng = Pcg::new(7);
        let dim = 64;
        let n = 4096;
        let mut idx = FlatIndex::new(dim);
        for i in 0..n {
            let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            idx.add(i as u64, &v);
        }
        let queries: Vec<Vec<f32>> = (0..16)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        bench("flat search k=10 (4096 x 64)", || {
            std::hint::black_box(idx.search(&queries[0], 10));
        })
        .report();
        bench("flat search_batch 16q k=10 (seq)", || {
            std::hint::black_box(idx.search_batch_with_threads(&qrefs, 10, 1));
        })
        .report();
        bench("flat search_batch 16q k=10 (4 shards)", || {
            std::hint::black_box(idx.search_batch_with_threads(&qrefs, 10, 4));
        })
        .report();
        let qidx = idx.quantize(Quant::Int8);
        bench("int8 flat search_batch 16q k=10 (seq)", || {
            std::hint::black_box(qidx.search_batch_with_threads(&qrefs, 10, 1));
        })
        .report();
    }

    section("embedding cache (capacity 10k, steady-state evictions)");
    {
        use windve::coordinator::cache::EmbeddingCache;
        const CAP: usize = 10_000;
        let cache = EmbeddingCache::new(CAP);
        let vec64 = vec![0.5f32; 64];
        for k in 0..CAP as u64 {
            cache.put(k, vec64.clone());
        }
        // Every put below evicts: this is the O(n)-scan hot spot the
        // linked-list LRU replaced (the old eviction walked all 10k
        // entries under the mutex per insert).
        let mut next = CAP as u64;
        bench("cache put (full @10k, evicting)", || {
            cache.put(next, vec64.clone());
            next += 1;
        })
        .report();
        bench("cache get hit (@10k)", || {
            std::hint::black_box(cache.get(next - 1));
        })
        .report();
        bench("cache get miss (@10k)", || {
            std::hint::black_box(cache.get(u64::MAX));
        })
        .report();
        let s = cache.snapshot();
        println!(
            "{:<44} {} evictions, {} entries (cap {})",
            "cache state after bench",
            s.evictions,
            s.entries,
            s.capacity
        );
    }

    section("queue manager (Algorithm 1)");
    {
        let qm = QueueManager::new(44, 8, true);
        bench("dispatch+release (NPU fastpath)", || {
            let r = qm.dispatch();
            qm.release(r);
        })
        .report();

        let qm_full = QueueManager::new(0, 0, true);
        bench("dispatch (BUSY path)", || {
            let _ = qm_full.dispatch();
        })
        .report();

        // Contended: 4 threads hammering one queue manager.
        let qm = Arc::new(QueueManager::new(44, 8, true));
        let iters = 200_000u64;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let qm = Arc::clone(&qm);
                std::thread::spawn(move || {
                    for _ in 0..iters {
                        let r = qm.dispatch();
                        if r != Route::Busy {
                            qm.release(r);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ns = t0.elapsed().as_nanos() as f64 / (4 * iters) as f64;
        println!("{:<44} {:>12.1} ns/op   (4-thread contended)", "dispatch+release contended", ns);
    }

    section("device queue / batcher");
    {
        let q: DeviceQueue<u32> = DeviceQueue::new();
        bench("push+drain_batch(16)", || {
            for i in 0..16 {
                q.push(Pending {
                    text: Arc::from(""),
                    class: WorkClass::Embed,
                    enqueued: Instant::now(),
                    trace: 0,
                    reply: i,
                });
            }
            let b = q.drain_batch(16).unwrap();
            std::hint::black_box(b.len());
        })
        .report();
    }

    section("tokenizer");
    {
        let mut gen = QueryGen::new(75, 1);
        let text = gen.query();
        bench("encode 75-token query (seq 80)", || {
            std::hint::black_box(tokenizer::encode(&text, 8192, 80));
        })
        .report();
        bench("token_count 75-token query", || {
            std::hint::black_box(tokenizer::token_count(&text));
        })
        .report();
    }

    section("metrics");
    {
        let h = Histogram::new();
        bench("histogram record", || h.record(123_456)).report();
        for i in 0..10_000 {
            h.record(i * 37);
        }
        bench("histogram p99", || {
            std::hint::black_box(h.quantile(0.99));
        })
        .report();

        // The hot-path lock fix: incrementing a counter by name takes
        // the registry mutex and walks the BTreeMap on every event;
        // the pre-resolved Arc handle (what the service caches in
        // HotMetrics at construction) is a single relaxed fetch_add.
        use windve::metrics::{ClassLabel, CodecLabel, Registry, RouteLabel, Stage, Tracer};
        let reg = Registry::new();
        for i in 0..64 {
            reg.counter(&format!("bench.filler.{i}"));
        }
        bench("counter inc (by-name lookup)", || {
            reg.counter("service.accepted").inc();
        })
        .report();
        let hot = reg.counter("service.accepted");
        bench("counter inc (pre-resolved Arc)", || hot.inc()).report();

        // One span record: label pack + seqlock ring publish + stage
        // histogram record, no heap allocation.
        let tracer = Tracer::new(&reg, 1024, std::time::Duration::from_millis(100));
        let id = tracer.mint();
        let t0 = Instant::now();
        bench("tracer span record", || {
            tracer.span(
                id,
                Stage::Embed,
                ClassLabel::Embed,
                RouteLabel::Npu,
                CodecLabel::All,
                t0,
                std::time::Duration::from_micros(5),
            );
        })
        .report();
    }

    section("json");
    {
        let v = Json::obj(vec![
            ("texts", Json::Arr(vec![Json::str("hello world embedding query"); 8])),
            ("slo", Json::num(1.0)),
        ]);
        let s = v.to_string();
        bench("encode /v1/embed-ish body", || {
            std::hint::black_box(v.to_string());
        })
        .report();
        bench("parse /v1/embed-ish body", || {
            std::hint::black_box(json::parse(&s).unwrap());
        })
        .report();
    }

    section("estimator + sim (table regeneration cost)");
    {
        let pts: Vec<(f64, f64)> = (1..=32).map(|c| (c as f64, 0.0166 * c as f64 + 0.27)).collect();
        bench("OLS fit (32 points)", || {
            std::hint::black_box(LinearFit::fit(&pts));
        })
        .report();
        let mut sim = ClosedLoopSim::new(
            DeviceProfile::v100_bge(),
            Some(DeviceProfile::xeon_e5_2690_bge()),
            44,
            8,
            75,
            1,
        );
        bench("closed-loop sim round (52 clients)", || {
            std::hint::black_box(sim.round(52));
        })
        .report();
    }
}
