//! Retrieval throughput: the concurrent-query capacity the batched,
//! SIMD-dispatched scan buys over the seed's one-query-at-a-time scalar
//! path — the retrieval half of the paper's cost formula — plus the
//! bandwidth win from scanning quantized (f16/int8) arenas.
//!
//! Compares, on a dim-768 corpus (env-tunable):
//! * per-query `search` (the seed serving pattern),
//! * `search_batch` sequential (panel kernel, one thread),
//! * `search_batch` sharded (panel kernel + scoped-thread scan),
//! for FlatIndex, then the same batched scan over f16/int8/pq8/pq4
//! arenas ([`QuantizedFlatIndex`]), plus the IvfIndex probe path per
//! codec and the per-panel ADC lookup-table build cost the PQ scans
//! amortize.
//!
//! Env knobs: `WINDVE_BENCH_ROWS` (default 16384), `WINDVE_BENCH_BATCH`
//! (default 32), `WINDVE_BENCH_MS` (per-case target, default 2000),
//! `WINDVE_SIMD=scalar` for a forced-scalar baseline run, `WINDVE_QUANT`
//! to pin one codec (default: every codec), and `WINDVE_BENCH_JSON=<path>`
//! to write the machine-readable record set CI uploads as an artifact.
//! The server-concurrency rows honor `WINDVE_BENCH_CONNS` (default 64)
//! and `WINDVE_BENCH_REQS` (keep-alive requests per conn, default 100).

use std::sync::Arc;
use std::time::Duration;

use windve::benchkit::{bench_with, section, JsonReport};
use windve::server::Server;
use windve::util::json::Json;
use windve::util::rng::Pcg;
use windve::vecstore::{kernels, FlatIndex, Index, IvfIndex, Quant};

const DIM: usize = 768;
const K: usize = 10;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
    v
}

/// Measure `f`, report queries/second, and append a JSON record.
struct Harness {
    rows: usize,
    batch: usize,
    target_ms: u64,
    report: JsonReport,
}

impl Harness {
    fn qps<F: FnMut()>(
        &mut self,
        name: &str,
        quant: Quant,
        queries_per_call: usize,
        mut f: F,
    ) -> f64 {
        let m = bench_with(name, self.target_ms, &mut f);
        let ns_per_query = m.mean_ns / queries_per_call as f64;
        let rate = 1e9 / ns_per_query;
        println!("{name:<52} {rate:>12.0} queries/s   (p99 call {:.2} ms)", m.p99_ns / 1e6);
        self.report.push(vec![
            ("bench", Json::str(name)),
            ("rows", Json::num(self.rows as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("quant", Json::str(quant.name())),
            ("kernel", Json::str(kernels::name())),
            ("bytes_per_row", Json::num(quant.bytes_per_row(DIM) as f64)),
            ("ns_per_query", Json::num(ns_per_query)),
            ("queries_per_s", Json::num(rate)),
        ]);
        rate
    }
}

fn main() {
    let rows = env_usize("WINDVE_BENCH_ROWS", 16384);
    let batch = env_usize("WINDVE_BENCH_BATCH", 32);
    let target_ms = env_usize("WINDVE_BENCH_MS", 2000) as u64;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let modes = Quant::modes_under_test();
    println!(
        "corpus {rows} x {DIM}, k={K}, batch={batch}, {threads} cores, kernel={}, codecs {:?}",
        kernels::name(),
        modes.iter().map(|q| q.name()).collect::<Vec<_>>()
    );

    let mut rng = Pcg::new(1);
    let mut flat = FlatIndex::new(DIM);
    for i in 0..rows {
        let v = unit(&mut rng, DIM);
        flat.add(i as u64, &v);
    }
    let queries: Vec<Vec<f32>> = (0..batch).map(|_| unit(&mut rng, DIM)).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

    let mut h = Harness { rows, batch, target_ms, report: JsonReport::new() };

    section("flat (exact) retrieval throughput, f32 baseline");
    let per_query = h.qps("per-query search (seed pattern)", Quant::F32, batch, || {
        for q in &qrefs {
            std::hint::black_box(flat.search(q, K));
        }
    });
    let batched_seq = h.qps("search_batch, 1 shard (panel kernel)", Quant::F32, batch, || {
        std::hint::black_box(flat.search_batch_with_threads(&qrefs, K, 1));
    });
    let batched_par = h.qps("search_batch, auto shards", Quant::F32, batch, || {
        std::hint::black_box(flat.search_batch(&qrefs, K));
    });
    println!(
        "{:<52} batch/seq {:.2}x, +shards {:.2}x",
        "speedup vs per-query search",
        batched_seq / per_query,
        batched_par / per_query
    );

    section("npu-offload scan (host fallback over the mirrored arena)");
    {
        let (ids, rows) = flat.export_f32_rows().expect("flat f32 exports a mirror");
        let scanner = windve::runtime::NpuScanner::from_snapshot(DIM, ids, rows, 0)
            .expect("mirror snapshot");
        let offload = h.qps("npu-offload search_batch (host fallback)", Quant::F32, batch, || {
            std::hint::black_box(scanner.search_batch(&qrefs, K));
        });
        println!(
            "{:<52} {:.2}x vs per-query (single-threaded mirror scan)",
            "offload fallback speedup",
            offload / per_query
        );
    }

    section("flat quantized arenas (same scan, fewer bytes)");
    for &quant in modes.iter().filter(|q| **q != Quant::F32) {
        let qidx = flat.quantize(quant);
        let f32_bytes = rows * Quant::F32.bytes_per_row(DIM);
        println!(
            "{:<52} {:.2}x fewer bytes scanned",
            format!("[{}] arena {} B/row", quant.name(), quant.bytes_per_row(DIM)),
            f32_bytes as f64 / qidx.arena_bytes() as f64
        );
        let seq_name = format!("search_batch, 1 shard [{}]", quant.name());
        let q_seq = h.qps(&seq_name, quant, batch, || {
            std::hint::black_box(qidx.search_batch_with_threads(&qrefs, K, 1));
        });
        let par_name = format!("search_batch, auto shards [{}]", quant.name());
        let q_par = h.qps(&par_name, quant, batch, || {
            std::hint::black_box(qidx.search_batch(&qrefs, K));
        });
        println!(
            "{:<52} seq {:.2}x, sharded {:.2}x",
            format!("[{}] speedup vs f32 search_batch", quant.name()),
            q_seq / batched_seq,
            q_par / batched_par
        );
    }

    section("pq adc lookup-table build (amortized once per query panel)");
    for &quant in &modes {
        let Quant::Pq { m, bits } = quant.resolved(DIM) else { continue };
        // Train on the staging prefix, exactly as the arena would.
        let train_rows = rows.min(256);
        let mut corpus = Vec::with_capacity(train_rows * DIM);
        for i in 0..train_rows {
            corpus.extend_from_slice(flat.vector(i));
        }
        let book = Arc::new(windve::vecstore::pq::Codebook::train(&corpus, DIM, m, bits, 1));
        let mut qbuf = Vec::with_capacity(batch * DIM);
        for q in &queries {
            qbuf.extend_from_slice(q);
        }
        // Reported per query: the k×m table of sub-space dots each query
        // pays once per panel, regardless of corpus size.
        h.qps(&format!("adc lut build [{}]", quant.name()), quant, batch, || {
            std::hint::black_box(book.build_lut(&qbuf, batch));
        });
    }

    section("ivf (nlist 64, nprobe 8) retrieval throughput");
    for &quant in &modes {
        let mut ivf = IvfIndex::with_quant(DIM, 64, 8, quant);
        // Rebuild from the flat corpus so every codec sees identical
        // rows (FlatIndex keeps the f32 originals).
        for i in 0..rows {
            ivf.add(i as u64, flat.vector(i));
        }
        ivf.build(2);
        let ivf_batched = h.qps(
            &format!("ivf search_batch (probe-list parallel) [{}]", quant.name()),
            quant,
            batch,
            || {
                std::hint::black_box(ivf.search_batch(&qrefs, K));
            },
        );
        if quant == Quant::F32 {
            let ivf_per_query = h.qps("ivf per-query search [f32]", quant, batch, || {
                for q in &qrefs {
                    std::hint::black_box(ivf.search(q, K));
                }
            });
            println!(
                "{:<52} {:.2}x",
                "ivf speedup vs per-query search",
                ivf_batched / ivf_per_query
            );
        }
    }

    section("http server concurrency: readiness loop vs thread-per-conn");
    {
        let conns = env_usize("WINDVE_BENCH_CONNS", 64);
        // One keep-alive connection serves at most MAX_REQUESTS_PER_CONN
        // requests before the server rotates it; stay under the cap.
        let reqs = env_usize("WINDVE_BENCH_REQS", 100)
            .clamp(1, windve::server::MAX_REQUESTS_PER_CONN - 1);
        let _ = windve::util::sys::raise_nofile_limit((4 * conns + 256) as u64);
        let svc = server_bench_service();
        let reactor = Server::start("127.0.0.1:0", Arc::clone(&svc), Duration::from_secs(2))
            .expect("reactor server");
        let qps_reactor = keepalive_qps(reactor.addr(), conns, reqs);
        reactor.stop();
        let threaded = Server::start_threaded("127.0.0.1:0", svc, Duration::from_secs(2))
            .expect("threaded server");
        let qps_threaded = keepalive_qps(threaded.addr(), conns, reqs);
        threaded.stop();
        for (name, qps) in [
            ("server keep-alive healthz, readiness loop", qps_reactor),
            ("server keep-alive healthz, thread-per-conn", qps_threaded),
        ] {
            println!("{name:<52} {qps:>12.0} requests/s   ({conns} conns x {reqs})");
            h.report.push(vec![
                ("bench", Json::str(name)),
                ("rows", Json::num(conns as f64)),
                ("batch", Json::num(reqs as f64)),
                ("quant", Json::str("f32")),
                ("kernel", Json::str(kernels::name())),
                ("queries_per_s", Json::num(qps)),
            ]);
        }
        println!(
            "{:<52} {:.2}x",
            "readiness loop vs thread-per-conn",
            qps_reactor / qps_threaded.max(1e-9)
        );
    }

    section("tracing overhead (embed path, traced vs untraced)");
    {
        let reqs = env_usize("WINDVE_BENCH_TRACE_REQS", 2000);
        let mut rates = Vec::new();
        for (name, capacity) in
            [("embed e2e, traced", 1024usize), ("embed e2e, untraced", 0)]
        {
            let svc = embed_bench_service(capacity);
            // Same driver both runs: mint_trace() is 0 when tracing is
            // off, so the only delta is the span pipeline itself.
            let start = std::time::Instant::now();
            for n in 0..reqs {
                let ticket = svc
                    .submit_traced(format!("trace bench query {n}"), svc.mint_trace())
                    .expect("depth 64, sequential: never busy");
                ticket.wait(Duration::from_secs(5)).expect("embed");
            }
            let qps = reqs as f64 / start.elapsed().as_secs_f64().max(1e-9);
            println!("{name:<52} {qps:>12.0} queries/s   ({reqs} sequential)");
            rates.push(qps);
            h.report.push(vec![
                ("bench", Json::str(name)),
                ("rows", Json::num(reqs as f64)),
                ("batch", Json::num(1.0)),
                ("quant", Json::str("f32")),
                ("kernel", Json::str(kernels::name())),
                ("queries_per_s", Json::num(qps)),
            ]);
            // Per-stage latency quantiles under the live schema, from
            // the traced run only (the untraced run records nothing).
            for (name, hist) in svc.metrics.histograms() {
                if !name.starts_with("trace.") || hist.count() == 0 {
                    continue;
                }
                println!(
                    "{:<52} p50 {:>8} ns  p95 {:>8} ns  p99 {:>8} ns  (n={})",
                    format!("stage {name}"),
                    hist.p50(),
                    hist.p95(),
                    hist.p99(),
                    hist.count()
                );
                h.report.push(vec![
                    ("bench", Json::str(format!("stage quantiles [{name}]"))),
                    ("rows", Json::num(reqs as f64)),
                    ("batch", Json::num(1.0)),
                    ("quant", Json::str("f32")),
                    ("kernel", Json::str(kernels::name())),
                    ("count", Json::num(hist.count() as f64)),
                    ("p50_ns", Json::num(hist.p50() as f64)),
                    ("p95_ns", Json::num(hist.p95() as f64)),
                    ("p99_ns", Json::num(hist.p99() as f64)),
                ]);
            }
        }
        println!(
            "{:<52} {:.2}% qps cost",
            "tracing overhead",
            (1.0 - rates[0] / rates[1].max(1e-9)) * 100.0
        );
    }

    if let Ok(path) = std::env::var("WINDVE_BENCH_JSON") {
        h.report.write(&path).expect("write bench JSON");
        println!("\nwrote {} records to {path}", h.report.len());
    }
}

/// NPU-only synthetic service for the tracing-overhead rows; the span
/// ring is the only knob that differs between the two runs.
fn embed_bench_service(trace_capacity: usize) -> std::sync::Arc<windve::coordinator::WindVE> {
    use windve::coordinator::{ServiceConfig, WindVE};
    use windve::devices::executor::{Backend, SyntheticBackend};
    use windve::devices::profile::DeviceProfile;
    std::sync::Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 64,
                cpu_depth: 0,
                hetero: false,
                npu_workers: 1,
                cpu_workers: 0,
                cache_entries: 0,
                trace_capacity,
                ..ServiceConfig::default()
            },
            vec![Box::new(|| {
                let mut p = DeviceProfile::v100_bge();
                p.noise_sigma = 0.0;
                p.outlier_prob = 0.0;
                Ok(Box::new(SyntheticBackend::new(p, 1e-6, 1)) as Box<dyn Backend>)
            })],
            vec![],
        )
        .expect("bench service"),
    )
}

/// Minimal NPU-only synthetic service for the server-concurrency rows
/// (healthz never touches the queues; the service just has to exist).
fn server_bench_service() -> std::sync::Arc<windve::coordinator::WindVE> {
    use windve::coordinator::{ServiceConfig, WindVE};
    use windve::devices::executor::{Backend, SyntheticBackend};
    use windve::devices::profile::DeviceProfile;
    std::sync::Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 64,
                cpu_depth: 0,
                hetero: false,
                npu_workers: 1,
                cpu_workers: 0,
                ..ServiceConfig::default()
            },
            vec![Box::new(|| {
                let mut p = DeviceProfile::v100_bge();
                p.noise_sigma = 0.0;
                p.outlier_prob = 0.0;
                Ok(Box::new(SyntheticBackend::new(p, 1e-6, 1)) as Box<dyn Backend>)
            })],
            vec![],
        )
        .expect("bench service"),
    )
}

/// Drive `conns` concurrent keep-alive connections, each issuing `reqs`
/// sequential `GET /v1/healthz` requests, and return aggregate
/// requests/second.
fn keepalive_qps(addr: std::net::SocketAddr, conns: usize, reqs: usize) -> f64 {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    let start = std::time::Instant::now();
    let clients: Vec<_> = (0..conns)
        .map(|c| {
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap_or_else(|e| panic!("conn {c}: {e}"));
                let req = b"GET /v1/healthz HTTP/1.1\r\nHost: b\r\n\r\n";
                let mut raw: Vec<u8> = Vec::with_capacity(512);
                let mut chunk = [0u8; 1024];
                for _ in 0..reqs {
                    s.write_all(req).unwrap();
                    // Read one response: head, then Content-Length bytes.
                    raw.clear();
                    let head_end = loop {
                        if let Some(p) = raw.windows(4).position(|w| w == b"\r\n\r\n") {
                            break p;
                        }
                        let n = s.read(&mut chunk).unwrap();
                        assert!(n > 0, "closed mid-response");
                        raw.extend_from_slice(&chunk[..n]);
                    };
                    let head = String::from_utf8_lossy(&raw[..head_end]);
                    let clen: usize = head
                        .lines()
                        .find_map(|l| {
                            l.to_ascii_lowercase()
                                .strip_prefix("content-length:")
                                .map(|v| v.trim().parse().unwrap())
                        })
                        .expect("Content-Length");
                    let mut have = raw.len() - head_end - 4;
                    while have < clen {
                        let n = s.read(&mut chunk).unwrap();
                        assert!(n > 0, "closed mid-body");
                        have += n;
                    }
                }
            })
        })
        .collect();
    for h in clients {
        h.join().expect("bench client panicked");
    }
    (conns * reqs) as f64 / start.elapsed().as_secs_f64().max(1e-9)
}
