//! Retrieval throughput: the concurrent-query capacity the batched,
//! SIMD-dispatched scan buys over the seed's one-query-at-a-time scalar
//! path — the retrieval half of the paper's cost formula.
//!
//! Compares, on a dim-768 corpus (env-tunable):
//! * per-query `search` (the seed serving pattern),
//! * `search_batch` sequential (panel kernel, one thread),
//! * `search_batch` sharded (panel kernel + scoped-thread scan),
//! for FlatIndex, plus the IvfIndex probe path.
//!
//! Env knobs: `WINDVE_BENCH_ROWS` (default 16384), `WINDVE_BENCH_BATCH`
//! (default 32), `WINDVE_SIMD=scalar` for a forced-scalar baseline run.

use windve::benchkit::{bench_with, section};
use windve::util::rng::Pcg;
use windve::vecstore::{kernels, FlatIndex, Index, IvfIndex};

const DIM: usize = 768;
const K: usize = 10;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
    v
}

/// Measure `f` with the shared benchkit harness and report it as
/// queries/second given `queries_per_call` per invocation.
fn qps<F: FnMut()>(name: &str, queries_per_call: usize, target_ms: u64, mut f: F) -> f64 {
    let m = bench_with(name, target_ms, &mut f);
    let rate = queries_per_call as f64 * 1e9 / m.mean_ns;
    println!("{name:<52} {rate:>12.0} queries/s   (p99 call {:.2} ms)", m.p99_ns / 1e6);
    rate
}

fn main() {
    let rows = env_usize("WINDVE_BENCH_ROWS", 16384);
    let batch = env_usize("WINDVE_BENCH_BATCH", 32);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "corpus {rows} x {DIM}, k={K}, batch={batch}, {threads} cores, kernel={}",
        kernels::name()
    );

    let mut rng = Pcg::new(1);
    let mut flat = FlatIndex::new(DIM);
    let mut ivf = IvfIndex::new(DIM, 64, 8);
    for i in 0..rows {
        let v = unit(&mut rng, DIM);
        flat.add(i as u64, &v);
        ivf.add(i as u64, &v);
    }
    ivf.build(2);
    let queries: Vec<Vec<f32>> = (0..batch).map(|_| unit(&mut rng, DIM)).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

    section("flat (exact) retrieval throughput");
    let per_query = qps("per-query search (seed pattern)", batch, 2000, || {
        for q in &qrefs {
            std::hint::black_box(flat.search(q, K));
        }
    });
    let batched_seq = qps("search_batch, 1 shard (panel kernel)", batch, 2000, || {
        std::hint::black_box(flat.search_batch_with_threads(&qrefs, K, 1));
    });
    let batched_par = qps("search_batch, auto shards", batch, 2000, || {
        std::hint::black_box(flat.search_batch(&qrefs, K));
    });
    println!(
        "{:<52} batch/seq {:.2}x, +shards {:.2}x",
        "speedup vs per-query search",
        batched_seq / per_query,
        batched_par / per_query
    );

    section("ivf (nlist 64, nprobe 8) retrieval throughput");
    let ivf_per_query = qps("per-query search", batch, 2000, || {
        for q in &qrefs {
            std::hint::black_box(ivf.search(q, K));
        }
    });
    let ivf_batched = qps("search_batch (per-probe-list parallel)", batch, 2000, || {
        std::hint::black_box(ivf.search_batch(&qrefs, K));
    });
    println!(
        "{:<52} {:.2}x",
        "speedup vs per-query search",
        ivf_batched / ivf_per_query
    );
}
