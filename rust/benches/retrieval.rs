//! Retrieval throughput: the concurrent-query capacity the batched,
//! SIMD-dispatched scan buys over the seed's one-query-at-a-time scalar
//! path — the retrieval half of the paper's cost formula — plus the
//! bandwidth win from scanning quantized (f16/int8) arenas.
//!
//! Compares, on a dim-768 corpus (env-tunable):
//! * per-query `search` (the seed serving pattern),
//! * `search_batch` sequential (panel kernel, one thread),
//! * `search_batch` sharded (panel kernel + scoped-thread scan),
//! for FlatIndex, then the same batched scan over f16/int8 arenas
//! ([`QuantizedFlatIndex`]), plus the IvfIndex probe path per codec.
//!
//! Env knobs: `WINDVE_BENCH_ROWS` (default 16384), `WINDVE_BENCH_BATCH`
//! (default 32), `WINDVE_BENCH_MS` (per-case target, default 2000),
//! `WINDVE_SIMD=scalar` for a forced-scalar baseline run, `WINDVE_QUANT`
//! to pin one codec (default: all three), and `WINDVE_BENCH_JSON=<path>`
//! to write the machine-readable record set CI uploads as an artifact.

use windve::benchkit::{bench_with, section, JsonReport};
use windve::util::json::Json;
use windve::util::rng::Pcg;
use windve::vecstore::{kernels, FlatIndex, Index, IvfIndex, Quant};

const DIM: usize = 768;
const K: usize = 10;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
    v
}

/// Measure `f`, report queries/second, and append a JSON record.
struct Harness {
    rows: usize,
    batch: usize,
    target_ms: u64,
    report: JsonReport,
}

impl Harness {
    fn qps<F: FnMut()>(
        &mut self,
        name: &str,
        quant: Quant,
        queries_per_call: usize,
        mut f: F,
    ) -> f64 {
        let m = bench_with(name, self.target_ms, &mut f);
        let ns_per_query = m.mean_ns / queries_per_call as f64;
        let rate = 1e9 / ns_per_query;
        println!("{name:<52} {rate:>12.0} queries/s   (p99 call {:.2} ms)", m.p99_ns / 1e6);
        self.report.push(vec![
            ("bench", Json::str(name)),
            ("rows", Json::num(self.rows as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("quant", Json::str(quant.name())),
            ("kernel", Json::str(kernels::name())),
            ("bytes_per_row", Json::num(quant.bytes_per_row(DIM) as f64)),
            ("ns_per_query", Json::num(ns_per_query)),
            ("queries_per_s", Json::num(rate)),
        ]);
        rate
    }
}

fn main() {
    let rows = env_usize("WINDVE_BENCH_ROWS", 16384);
    let batch = env_usize("WINDVE_BENCH_BATCH", 32);
    let target_ms = env_usize("WINDVE_BENCH_MS", 2000) as u64;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let modes = Quant::modes_under_test();
    println!(
        "corpus {rows} x {DIM}, k={K}, batch={batch}, {threads} cores, kernel={}, codecs {:?}",
        kernels::name(),
        modes.iter().map(|q| q.name()).collect::<Vec<_>>()
    );

    let mut rng = Pcg::new(1);
    let mut flat = FlatIndex::new(DIM);
    for i in 0..rows {
        let v = unit(&mut rng, DIM);
        flat.add(i as u64, &v);
    }
    let queries: Vec<Vec<f32>> = (0..batch).map(|_| unit(&mut rng, DIM)).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

    let mut h = Harness { rows, batch, target_ms, report: JsonReport::new() };

    section("flat (exact) retrieval throughput, f32 baseline");
    let per_query = h.qps("per-query search (seed pattern)", Quant::F32, batch, || {
        for q in &qrefs {
            std::hint::black_box(flat.search(q, K));
        }
    });
    let batched_seq = h.qps("search_batch, 1 shard (panel kernel)", Quant::F32, batch, || {
        std::hint::black_box(flat.search_batch_with_threads(&qrefs, K, 1));
    });
    let batched_par = h.qps("search_batch, auto shards", Quant::F32, batch, || {
        std::hint::black_box(flat.search_batch(&qrefs, K));
    });
    println!(
        "{:<52} batch/seq {:.2}x, +shards {:.2}x",
        "speedup vs per-query search",
        batched_seq / per_query,
        batched_par / per_query
    );

    section("npu-offload scan (host fallback over the mirrored arena)");
    {
        let (ids, rows) = flat.export_f32_rows().expect("flat f32 exports a mirror");
        let scanner = windve::runtime::NpuScanner::from_snapshot(DIM, ids, rows, 0)
            .expect("mirror snapshot");
        let offload = h.qps("npu-offload search_batch (host fallback)", Quant::F32, batch, || {
            std::hint::black_box(scanner.search_batch(&qrefs, K));
        });
        println!(
            "{:<52} {:.2}x vs per-query (single-threaded mirror scan)",
            "offload fallback speedup",
            offload / per_query
        );
    }

    section("flat quantized arenas (same scan, fewer bytes)");
    for &quant in modes.iter().filter(|q| **q != Quant::F32) {
        let qidx = flat.quantize(quant);
        let f32_bytes = rows * Quant::F32.bytes_per_row(DIM);
        println!(
            "{:<52} {:.2}x fewer bytes scanned",
            format!("[{}] arena {} B/row", quant.name(), quant.bytes_per_row(DIM)),
            f32_bytes as f64 / qidx.arena_bytes() as f64
        );
        let seq_name = format!("search_batch, 1 shard [{}]", quant.name());
        let q_seq = h.qps(&seq_name, quant, batch, || {
            std::hint::black_box(qidx.search_batch_with_threads(&qrefs, K, 1));
        });
        let par_name = format!("search_batch, auto shards [{}]", quant.name());
        let q_par = h.qps(&par_name, quant, batch, || {
            std::hint::black_box(qidx.search_batch(&qrefs, K));
        });
        println!(
            "{:<52} seq {:.2}x, sharded {:.2}x",
            format!("[{}] speedup vs f32 search_batch", quant.name()),
            q_seq / batched_seq,
            q_par / batched_par
        );
    }

    section("ivf (nlist 64, nprobe 8) retrieval throughput");
    for &quant in &modes {
        let mut ivf = IvfIndex::with_quant(DIM, 64, 8, quant);
        // Rebuild from the flat corpus so every codec sees identical
        // rows (FlatIndex keeps the f32 originals).
        for i in 0..rows {
            ivf.add(i as u64, flat.vector(i));
        }
        ivf.build(2);
        let ivf_batched = h.qps(
            &format!("ivf search_batch (probe-list parallel) [{}]", quant.name()),
            quant,
            batch,
            || {
                std::hint::black_box(ivf.search_batch(&qrefs, K));
            },
        );
        if quant == Quant::F32 {
            let ivf_per_query = h.qps("ivf per-query search [f32]", quant, batch, || {
                for q in &qrefs {
                    std::hint::black_box(ivf.search(q, K));
                }
            });
            println!(
                "{:<52} {:.2}x",
                "ivf speedup vs per-query search",
                ivf_batched / ivf_per_query
            );
        }
    }

    if let Ok(path) = std::env::var("WINDVE_BENCH_JSON") {
        h.report.write(&path).expect("write bench JSON");
        println!("\nwrote {} records to {path}", h.report.len());
    }
}
