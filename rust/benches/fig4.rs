//! Bench target regenerating Figure 4: latency-vs-concurrency fits.
//! Asserts the paper's fitted-coefficient relations: β_CPU > β_NPU per
//! pair and α ratios ≈ 0.21 (V100/Xeon) and ≈ 0.12 (Atlas/Kunpeng).

use windve::repro::fig4;

fn main() {
    let fits = fig4::run(42);
    fig4::print(&fits);

    let mut failures = Vec::new();
    for f in &fits {
        if (f.beta - f.paper_beta).abs() > 0.15 {
            failures.push(format!("{} β {:.3} vs paper {:.2}", f.device, f.beta, f.paper_beta));
        }
    }
    if fits[1].beta <= fits[0].beta {
        failures.push("β_Xeon must exceed β_V100 (Ineq. 15)".into());
    }
    if fits[3].beta <= fits[2].beta {
        failures.push("β_Kunpeng must exceed β_Atlas (Ineq. 15)".into());
    }
    let r1 = fits[0].alpha / fits[1].alpha;
    let r2 = fits[2].alpha / fits[3].alpha;
    if (r1 - 0.21).abs() > 0.06 {
        failures.push(format!("V100/Xeon α ratio {r1:.3} vs paper 0.21"));
    }
    if (r2 - 0.12).abs() > 0.06 {
        failures.push(format!("Atlas/Kunpeng α ratio {r2:.3} vs paper 0.12"));
    }
    if failures.is_empty() {
        println!("\nSHAPE OK — Figure 4 coefficient structure reproduced");
    } else {
        for f in &failures {
            println!("SHAPE MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
