//! Integration tests over the built AOT artifacts: the full
//! python-AOT → HLO text → PJRT compile → execute path, checked against
//! the golden outputs exported by `python/compile/aot.py`.
//!
//! Skipped (with a notice) when `artifacts/` has not been built — run
//! `make artifacts` first.

use std::path::PathBuf;

use windve::runtime::{engine::cosine, tokenizer, EmbeddingEngine, Manifest};
use windve::util::json::{self, Json};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn load_golden(dir: &PathBuf) -> Json {
    let text = std::fs::read_to_string(dir.join("golden.json")).expect("golden.json");
    json::parse(&text).expect("parse golden.json")
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(!m.models.is_empty());
    for entry in &m.models {
        assert!(entry.max_batch() >= 1);
        for b in &entry.buckets {
            assert!(dir.join(&b.file).exists(), "missing {}", b.file);
        }
        assert!(dir.join(&entry.weights_file).exists());
    }
}

#[test]
fn tokenizer_parity_with_python() {
    let Some(dir) = artifacts_dir() else { return };
    let golden = load_golden(&dir);
    let vocab = 8192u32;
    for (word, expected) in golden.get("tokenizer_parity").unwrap().as_obj().unwrap() {
        let got = tokenizer::word_id(word, vocab);
        assert_eq!(
            got as u64,
            expected.as_u64().unwrap(),
            "token id mismatch for word {word:?}"
        );
    }
    // Full-text parity: re-encode the golden texts and compare ids+mask.
    let seq = golden.get("seq").unwrap().as_usize().unwrap();
    let texts = golden.get("texts").unwrap().as_arr().unwrap();
    let ids = golden.get("token_ids").unwrap().as_arr().unwrap();
    let masks = golden.get("mask").unwrap().as_arr().unwrap();
    for ((t, id_row), mask_row) in texts.iter().zip(ids).zip(masks) {
        let e = tokenizer::encode(t.as_str().unwrap(), vocab, seq);
        let want_ids: Vec<i32> = id_row
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let want_mask: Vec<f32> = mask_row
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(e.ids, want_ids, "ids for {:?}", t.as_str().unwrap());
        assert_eq!(e.mask, want_mask);
    }
}

#[test]
fn golden_embeddings_match_jax() {
    let Some(dir) = artifacts_dir() else { return };
    let golden = load_golden(&dir);
    let model = golden.get("model").unwrap().as_str().unwrap();
    let texts: Vec<String> = golden
        .get("texts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_str().unwrap().to_string())
        .collect();

    let mut engine = EmbeddingEngine::load(&dir, model).unwrap();
    let got = engine.embed(&texts).unwrap();

    let want = golden.get("embeddings").unwrap().as_arr().unwrap();
    assert_eq!(got.len(), want.len());
    for (row_got, row_want) in got.iter().zip(want) {
        let row_want: Vec<f32> = row_want
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(row_got.len(), row_want.len());
        for (a, b) in row_got.iter().zip(&row_want) {
            assert!(
                (a - b).abs() < 1e-4,
                "embedding mismatch: rust={a} jax={b}"
            );
        }
    }
}

#[test]
fn embeddings_are_unit_norm_and_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = EmbeddingEngine::load(&dir, "bge_micro").unwrap();
    let texts = vec![
        "the quick brown fox".to_string(),
        "jumps over the lazy dog".to_string(),
    ];
    let a = engine.embed(&texts).unwrap();
    let b = engine.embed(&texts).unwrap();
    assert_eq!(a, b, "same input must embed identically");
    for row in &a {
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
    }
}

#[test]
fn batch_equals_solo_embedding() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = EmbeddingEngine::load(&dir, "bge_micro").unwrap();
    let texts: Vec<String> = (0..4).map(|i| format!("query number {i} about rag")).collect();
    let batched = engine.embed(&texts).unwrap();
    let solo = engine.embed(&texts[..1].to_vec()).unwrap();
    for (a, b) in batched[0].iter().zip(&solo[0]) {
        assert!((a - b).abs() < 1e-4, "batch vs solo drift: {a} vs {b}");
    }
}

#[test]
fn oversized_batch_chunks_transparently() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = EmbeddingEngine::load(&dir, "bge_micro").unwrap();
    let n = engine.max_batch() * 2 + 3;
    let texts: Vec<String> = (0..n).map(|i| format!("chunked query {i}")).collect();
    let out = engine.embed(&texts).unwrap();
    assert_eq!(out.len(), n);
}

#[test]
fn long_text_truncates_to_max_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = EmbeddingEngine::load(&dir, "bge_micro").unwrap();
    let long = (0..2000).map(|i| format!("tok{i}")).collect::<Vec<_>>().join(" ");
    let out = engine.embed(&[long]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), engine.d_model());
}

#[test]
fn same_tokens_same_vector_different_tokens_different_vector() {
    // With random weights this is a *consistency* check (same tokens →
    // same vector; different tokens → different vector), not semantics.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = EmbeddingEngine::load(&dir, "bge_micro").unwrap();
    let out = engine
        .embed(&[
            "alpha beta gamma".to_string(),
            "ALPHA beta; gamma!".to_string(), // same tokens after normalisation
            "completely different words here".to_string(),
        ])
        .unwrap();
    let same = cosine(&out[0], &out[1]);
    let diff = cosine(&out[0], &out[2]);
    assert!((same - 1.0).abs() < 1e-4, "identical token streams: {same}");
    assert!(diff < 0.999, "different texts suspiciously identical: {diff}");
}

#[test]
fn jina_model_also_serves() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = EmbeddingEngine::load(&dir, "jina_micro").unwrap();
    let out = engine.embed(&["jina micro smoke".to_string()]).unwrap();
    assert_eq!(out[0].len(), engine.d_model());
    assert_eq!(engine.d_model(), 384);
}
