//! Concurrency model checking for the admission, executor-handshake and
//! cache hot paths, under [loom](https://docs.rs/loom).
//!
//! This target is empty in normal test runs. Loom swaps every sync
//! primitive the shim (`windve::util::sync`) wraps for instrumented
//! twins and exhaustively explores thread interleavings, so each
//! `#[test]` here is a *proof over all schedules* (up to the preemption
//! bound), not a probabilistic stress run. Build and run with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --test loom_admission --release
//! ```
//!
//! `LOOM_MAX_PREEMPTIONS` (default 2) bounds how many times the model
//! checker forcibly preempts a thread at an atomic access; 2-3 catches
//! practically all ordering bugs (loom's own guidance) while keeping
//! the state space tractable for CI.
//!
//! What is covered, mirroring the paper's admission design:
//!
//! * `admission` — every `(WorkClass, leg)` pair of the weighted
//!   multi-class queue manager (paper Eq. 9-10): pool caps never
//!   exceeded, per-class sums equal pool occupancy at rest, cap
//!   rollback leaves no residue, double release is contained, every
//!   schedule drains to zero.
//! * `guard` — the RAII [`AdmissionGuard`] releases exactly once under
//!   every interleaving of its drop with concurrent admissions.
//! * `executor` — the corpus version/mirror handshake: a reader that
//!   observes version `v` also observes every row committed before the
//!   bump to `v`; exports are consistent cuts; the poisoned-lock
//!   recovery path counts and recovers.
//! * `cache` — the LRU stats snapshot: `hits + misses == gets`, `len`
//!   never exceeds capacity, evictions account for the overflow.
//! * `trace` — the span-ring seqlock: a snapshot racing writers never
//!   surfaces a torn record, same-slot claim races drop (not mix)
//!   records, and capacity is a hard bound in every schedule.
#![cfg(loom)]

mod harness {
    /// Run `f` under loom's exhaustive model checker with a bounded
    /// number of forced preemptions (see module docs).
    pub fn model<F>(f: F)
    where
        F: Fn() + Sync + Send + 'static,
    {
        let mut builder = loom::model::Builder::new();
        let bound = std::env::var("LOOM_MAX_PREEMPTIONS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2);
        builder.preemption_bound = Some(bound);
        builder.check(f);
    }
}

#[path = "loom/admission.rs"]
mod admission;
#[path = "loom/guard.rs"]
mod guard;
#[path = "loom/executor.rs"]
mod executor;
#[path = "loom/cache.rs"]
mod cache;
#[path = "loom/trace.rs"]
mod trace;
