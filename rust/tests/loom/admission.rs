//! Loom models for `QueueManager::dispatch_class` / `release_class`
//! across every `(WorkClass, leg)` pair.
//!
//! Invariants proved over all interleavings (up to the preemption
//! bound):
//!
//! 1. pool occupancy never exceeds the configured depth, and per-class
//!    occupancy never exceeds its cap;
//! 2. at rest (all threads joined, nothing mid-admission) the per-class
//!    occupancies sum to the pool occupancy — transiently the class
//!    counter may lead the pool (cap-then-pool order), which is why the
//!    sum is only asserted at join points;
//! 3. a cap winner that loses the pool race rolls its cap back with no
//!    residue;
//! 4. double release is contained: it cannot free another class's held
//!    slot and it increments `bad_releases`;
//! 5. every schedule drains to zero occupancy.

use crate::harness::model;
use loom::sync::Arc;
use loom::thread;
use windve::coordinator::{ClassCaps, QueueManager, Route, WorkClass};

/// Per-class sums == pool occupancy, both legs. Valid only at rest.
fn assert_sums(qm: &QueueManager) {
    assert_eq!(
        qm.embed_cpu_occupancy() + qm.retrieve_cpu_occupancy() + qm.ingest_cpu_occupancy(),
        qm.cpu_occupancy(),
        "CPU per-class occupancies must sum to the pool at rest"
    );
    assert_eq!(
        qm.embed_npu_occupancy() + qm.retrieve_npu_occupancy() + qm.ingest_npu_occupancy(),
        qm.npu_occupancy(),
        "NPU per-class occupancies must sum to the pool at rest"
    );
}

/// Two embeds race a depth-1 NPU pool: the cap holds mid-flight, at
/// least one admission succeeds, accounting balances, and releasing
/// drains to zero.
#[test]
fn embed_npu_pool_cap_never_exceeded() {
    model(|| {
        let qm = Arc::new(QueueManager::new(1, 0, false));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let qm = Arc::clone(&qm);
                thread::spawn(move || {
                    let route = qm.dispatch();
                    // Observed from inside the race: the pool bound is
                    // a hard invariant, not just a steady-state one.
                    assert!(qm.npu_occupancy() <= 1, "NPU pool cap breached");
                    if route == Route::Npu {
                        qm.release(Route::Npu);
                    }
                    route
                })
            })
            .collect();
        let routes: Vec<Route> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // A depth-1 pool admits at least one of two contenders in every
        // schedule — `try_acquire` only fails when genuinely full.
        assert!(routes.iter().any(|r| *r == Route::Npu));
        assert_eq!(qm.npu_occupancy(), 0, "drain to zero");
        assert_sums(&qm);
        let stats = qm.stats();
        assert_eq!(stats.routed_npu + stats.rejected, 2);
        assert_eq!(stats.bad_releases, 0);
    });
}

/// Hetero deployment, one slot per device: two embeds racing can never
/// both be rejected (Algorithm 1's CPU overflow), and the slots they
/// hold are accounted exactly.
#[test]
fn embed_overflows_to_cpu_when_npu_full() {
    model(|| {
        let qm = Arc::new(QueueManager::new(1, 1, true));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let qm = Arc::clone(&qm);
                thread::spawn(move || qm.dispatch())
            })
            .collect();
        let routes: Vec<Route> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Capacity 2 across both devices, two contenders, no releases
        // mid-race: rejecting either would need both pools full, which
        // the other thread alone cannot achieve.
        assert!(routes.iter().all(|r| *r != Route::Busy));
        assert_eq!(qm.npu_occupancy() + qm.cpu_occupancy(), 2);
        assert_sums(&qm);
        for route in routes {
            qm.release(route);
        }
        assert_eq!(qm.npu_occupancy() + qm.cpu_occupancy(), 0);
        let stats = qm.stats();
        assert_eq!(stats.routed_npu + stats.routed_cpu, 2);
        assert_eq!(stats.rejected, 0);
    });
}

/// Retrieve and Ingest with disjoint caps share the CPU pool without
/// interfering: both admit, per-class sums match the pool, and releases
/// drain to zero.
#[test]
fn retrieve_and_ingest_share_cpu_pool() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            0,
            2,
            false,
            ClassCaps {
                retrieve: 1,
                ingest: 1,
                ..ClassCaps::default()
            },
        ));
        let retr = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch_class(WorkClass::Retrieve, 1))
        };
        let ingest = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch_class(WorkClass::Ingest, 1))
        };
        // Caps 1+1 fit the depth-2 pool exactly: neither admission can
        // fail in any schedule.
        assert_eq!(retr.join().unwrap(), Route::Cpu);
        assert_eq!(ingest.join().unwrap(), Route::Cpu);
        assert_eq!(qm.retrieve_cpu_occupancy(), 1);
        assert_eq!(qm.ingest_cpu_occupancy(), 1);
        assert_eq!(qm.cpu_occupancy(), 2);
        assert_sums(&qm);
        qm.release_class(WorkClass::Retrieve, Route::Cpu, 1);
        qm.release_class(WorkClass::Ingest, Route::Cpu, 1);
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_sums(&qm);
        assert_eq!(qm.stats().bad_releases, 0);
    });
}

/// Cap-then-pool rollback: a retrieval that wins its cap but loses the
/// depth-1 pool to an embed must roll the cap acquisition back — a
/// stale `retr_cpu` credit here would silently shrink the scan budget
/// forever.
#[test]
fn retrieve_rollback_leaves_no_residue() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            0,
            1,
            true,
            ClassCaps {
                retrieve: 1,
                ..ClassCaps::default()
            },
        ));
        let embed = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch())
        };
        let retr = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch_class(WorkClass::Retrieve, 1))
        };
        let embed_route = embed.join().unwrap();
        let retr_route = retr.join().unwrap();
        // Exactly one of the two holds the single CPU slot.
        assert_eq!(qm.cpu_occupancy(), 1);
        assert!((embed_route == Route::Cpu) ^ (retr_route == Route::Cpu));
        if retr_route == Route::Busy {
            assert_eq!(
                qm.retrieve_cpu_occupancy(),
                0,
                "pool-loss rollback left cap residue"
            );
        }
        if embed_route == Route::Busy {
            assert_eq!(qm.embed_cpu_occupancy(), 0);
        }
        assert_sums(&qm);
        if embed_route == Route::Cpu {
            qm.release(Route::Cpu);
        } else {
            qm.release_class(WorkClass::Retrieve, Route::Cpu, 1);
        }
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
    });
}

/// All three classes contending for a depth-2 NPU pool under unit caps:
/// exactly two admit in every schedule, class caps and the pool bound
/// hold, and mixed-class releases drain cleanly.
#[test]
fn three_classes_contend_for_npu_pool() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            2,
            0,
            false,
            ClassCaps {
                npu_retrieve: 1,
                npu_ingest: 1,
                ..ClassCaps::default()
            },
        ));
        let embed = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch())
        };
        let retr = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch_retrieve_npu(1))
        };
        let ingest = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || qm.dispatch_ingest_npu(1))
        };
        let routes = [
            (WorkClass::Embed, embed.join().unwrap()),
            (WorkClass::Retrieve, retr.join().unwrap()),
            (WorkClass::Ingest, ingest.join().unwrap()),
        ];
        let admitted = routes.iter().filter(|(_, r)| *r == Route::Npu).count();
        // Three unit-cost contenders over a depth-2 pool: admissions
        // only fail when full, so exactly two must win.
        assert_eq!(admitted, 2);
        assert_eq!(qm.npu_occupancy(), 2);
        assert!(qm.retrieve_npu_occupancy() <= 1, "npu_retrieve cap breached");
        assert!(qm.ingest_npu_occupancy() <= 1, "npu_ingest cap breached");
        assert_sums(&qm);
        for (class, route) in routes {
            if route == Route::Npu {
                qm.release_class(class, Route::Npu, 1);
            }
        }
        assert_eq!(qm.npu_occupancy(), 0);
        assert_sums(&qm);
        assert_eq!(qm.stats().bad_releases, 0);
    });
}

/// Double release is contained: releasing a retrieval twice must not
/// liberate the ingest slot still held, must leave the pool consistent,
/// and must be observable via `bad_releases`.
#[test]
fn double_release_cannot_free_other_class() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            0,
            2,
            false,
            ClassCaps {
                retrieve: 1,
                ingest: 1,
                ..ClassCaps::default()
            },
        ));
        let retr = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 1), Route::Cpu);
                qm.release_class(WorkClass::Retrieve, Route::Cpu, 1);
                // Buggy caller: second release of the same admission.
                qm.release_class(WorkClass::Retrieve, Route::Cpu, 1);
            })
        };
        let ingest = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Cpu);
            })
        };
        retr.join().unwrap();
        ingest.join().unwrap();
        // The ingest admission survives the retrieval double-free: only
        // the amount actually freed from `retr_cpu` (zero, the second
        // time) is credited back to the pool.
        assert_eq!(qm.ingest_cpu_occupancy(), 1);
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        assert_eq!(qm.cpu_occupancy(), 1);
        assert_sums(&qm);
        assert!(qm.stats().bad_releases >= 1, "double release must be counted");
        qm.release_class(WorkClass::Ingest, Route::Cpu, 1);
        assert_eq!(qm.cpu_occupancy(), 0);
    });
}

/// Weighted costs (Eq. 9's cost-proportional admission): two cost-2
/// scans against a cap of 3 — the cap bound holds mid-flight and every
/// schedule drains exactly, with admissions + rejections accounting for
/// both attempts.
#[test]
fn weighted_cost_admissions_drain_exactly() {
    model(|| {
        let qm = Arc::new(QueueManager::with_class_caps(0, 4, false, 3, 0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let qm = Arc::clone(&qm);
                thread::spawn(move || {
                    let route = qm.dispatch_class(WorkClass::Retrieve, 2);
                    assert!(qm.retrieve_cpu_occupancy() <= 3, "retrieve cap breached");
                    if route == Route::Cpu {
                        qm.release_class(WorkClass::Retrieve, Route::Cpu, 2);
                    }
                    route
                })
            })
            .collect();
        let routes: Vec<Route> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Cost 2 against a cap of 3: at least one admission fits.
        assert!(routes.iter().any(|r| *r == Route::Cpu));
        assert_eq!(qm.cpu_occupancy(), 0, "weighted drain must be exact");
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        assert_sums(&qm);
        let stats = qm.stats();
        assert_eq!(stats.routed_retrieve + stats.rejected_retrieve, 2);
        assert_eq!(stats.bad_releases, 0);
    });
}

/// Releasing into an empty manager never underflows the saturating
/// counters, even racing a live admission on the other class.
#[test]
fn release_on_empty_never_underflows() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            1,
            1,
            true,
            ClassCaps {
                retrieve: 1,
                ..ClassCaps::default()
            },
        ));
        let stray = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                // Nothing was ever admitted for Retrieve.
                qm.release_class(WorkClass::Retrieve, Route::Cpu, 1);
            })
        };
        let embed = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                let route = qm.dispatch();
                assert_ne!(route, Route::Busy);
                route
            })
        };
        stray.join().unwrap();
        let route = embed.join().unwrap();
        // The stray release must not have freed (or corrupted) the
        // embed's slot, nor wrapped any counter.
        assert_eq!(qm.npu_occupancy() + qm.cpu_occupancy(), 1);
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        assert_sums(&qm);
        assert!(qm.stats().bad_releases >= 1);
        qm.release(route);
        assert_eq!(qm.npu_occupancy() + qm.cpu_occupancy(), 0);
    });
}
