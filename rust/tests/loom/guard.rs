//! Loom models for the RAII [`AdmissionGuard`]: dropping the guard
//! releases the admission exactly once, under every interleaving with
//! concurrent admissions on the same pools.

use crate::harness::model;
use loom::sync::Arc;
use loom::thread;
use windve::coordinator::{ClassCaps, QueueManager, Route, WorkClass};

/// A guard-scoped NPU retrieval racing an embed on the same pool:
/// whatever the schedule, the guard's drop returns exactly the cost it
/// covered and the manager drains to zero with no bad releases.
#[test]
fn guard_drop_releases_exactly_once() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            2,
            0,
            false,
            ClassCaps {
                npu_retrieve: 2,
                ..ClassCaps::default()
            },
        ));
        let scan = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                if qm.dispatch_retrieve_npu(2) == Route::Npu {
                    let guard = qm.guard(WorkClass::Retrieve, Route::Npu, 2);
                    assert_eq!(guard.route(), Route::Npu);
                    assert_eq!(guard.cost(), 2);
                    assert_eq!(qm.retrieve_npu_occupancy(), 2);
                    drop(guard);
                    // The drop freed the scan's own slots — nothing
                    // else holds the retrieval leg.
                    assert_eq!(qm.retrieve_npu_occupancy(), 0);
                    true
                } else {
                    false
                }
            })
        };
        let embed = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                let route = qm.dispatch();
                if route == Route::Npu {
                    qm.release(Route::Npu);
                }
            })
        };
        let admitted = scan.join().unwrap();
        embed.join().unwrap();
        // Cost 2 against a depth-2 pool can lose to the embed's unit
        // admission in some schedules; either way everything drains.
        let _ = admitted;
        assert_eq!(qm.npu_occupancy(), 0);
        assert_eq!(qm.retrieve_npu_occupancy(), 0);
        assert_eq!(qm.embed_npu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
    });
}

/// Two guard-scoped admissions of different classes dropping
/// concurrently: each drop frees only its own class's slots.
#[test]
fn concurrent_guard_drops_stay_classwise() {
    model(|| {
        let qm = Arc::new(QueueManager::with_caps(
            0,
            2,
            false,
            ClassCaps {
                retrieve: 1,
                ingest: 1,
                ..ClassCaps::default()
            },
        ));
        let retr = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 1), Route::Cpu);
                let guard = qm.guard(WorkClass::Retrieve, Route::Cpu, 1);
                drop(guard);
                assert_eq!(qm.retrieve_cpu_occupancy(), 0);
            })
        };
        let ingest = {
            let qm = Arc::clone(&qm);
            thread::spawn(move || {
                assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Cpu);
                let guard = qm.guard(WorkClass::Ingest, Route::Cpu, 1);
                drop(guard);
                assert_eq!(qm.ingest_cpu_occupancy(), 0);
            })
        };
        retr.join().unwrap();
        ingest.join().unwrap();
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
    });
}
