//! Loom models for the span-ring seqlock (PR 10): concurrent writers
//! and a racing snapshot can never surface a torn record — a reader
//! either sees a slot's four fields from one coherent write or skips
//! the slot entirely.

use crate::harness::model;
use loom::sync::Arc;
use loom::thread;
use windve::metrics::{ClassLabel, CodecLabel, RouteLabel, SpanRecord, SpanRing, Stage};

/// A record whose fields are all derived from `trace_id` — any mix of
/// fields from two different writes is detectable.
fn rec(trace_id: u64) -> SpanRecord {
    SpanRecord {
        trace_id,
        stage: Stage::Embed,
        class: ClassLabel::Embed,
        route: RouteLabel::Npu,
        codec: CodecLabel::All,
        start_ns: trace_id * 10,
        dur_ns: trace_id * 3,
    }
}

fn coherent(r: &SpanRecord) -> bool {
    r.start_ns == r.trace_id * 10 && r.dur_ns == r.trace_id * 3
}

/// Two writers racing a capacity-2 ring while a reader snapshots
/// mid-flight: every record the snapshot returns is coherent (the
/// seqlock revalidation discarded anything torn), and the final
/// snapshot sees both records.
#[test]
fn snapshot_never_observes_a_torn_record() {
    model(|| {
        let ring = Arc::new(SpanRing::new(2));
        let writers: Vec<_> = (1..=2u64)
            .map(|id| {
                let ring = Arc::clone(&ring);
                thread::spawn(move || ring.push(rec(id)))
            })
            .collect();
        let reader = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                for r in ring.snapshot() {
                    assert!(coherent(&r), "torn record surfaced: {r:?}");
                }
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        reader.join().unwrap();
        // At rest the ring holds exactly the two coherent records.
        let fin = ring.snapshot();
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(coherent));
        let mut ids: Vec<u64> = fin.iter().map(|r| r.trace_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
    });
}

/// Overwrite-oldest under same-slot contention: three pushes racing a
/// capacity-1 ring never tear and never exceed the bound. The slot
/// claim serializes writers, so at most one record survives — coherent
/// in every schedule — and claim-race losers are dropped, not mixed.
#[test]
fn overwrite_oldest_is_bounded_and_coherent() {
    model(|| {
        let ring = Arc::new(SpanRing::new(1));
        let writers: Vec<_> = (1..=3u64)
            .map(|id| {
                let ring = Arc::clone(&ring);
                thread::spawn(move || ring.push(rec(id)))
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        let fin = ring.snapshot();
        assert!(fin.len() <= 1, "capacity-1 ring held {} records", fin.len());
        for r in &fin {
            assert!(coherent(r), "torn record surfaced: {r:?}");
            assert!((1..=3).contains(&r.trace_id));
        }
        assert_eq!(ring.recorded(), 3);
        assert_eq!(ring.dropped(), 2);
    });
}
