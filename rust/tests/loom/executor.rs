//! Loom models for the `RetrievalExecutor` corpus version/mirror
//! handshake and its poisoned-lock recovery path.
//!
//! The handshake contract under test: `add()` bumps the version with
//! Release *inside* the write guard, and `version()` loads Acquire — so
//! any thread that observes version `v` also observes every row
//! mutation committed before the bump to `v`. The NPU mirror sync and
//! snapshot export both lean on exactly this edge.

use crate::harness::model;
use loom::sync::Arc;
use loom::thread;
use windve::devices::executor::RetrievalExecutor;

/// Writer commits one row; a racing reader that observes the version
/// bump must also observe the row. This is the publication edge the
/// mirror-staleness check depends on — with a Relaxed bump loom finds
/// the schedule where the reader sees version 1 but zero rows.
#[test]
fn version_bump_publishes_rows() {
    model(|| {
        let ex = Arc::new(RetrievalExecutor::flat(2));
        let writer = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || ex.add(7, &[1.0, 0.0]))
        };
        let reader = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || {
                if ex.version() >= 1 {
                    // Acquire saw the Release bump, so the row mutation
                    // (sequenced before the bump, inside the same write
                    // guard) must be visible too.
                    assert_eq!(ex.len(), 1, "version visible before its rows");
                }
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
        assert_eq!(ex.version(), 1);
        assert_eq!(ex.len(), 1);
    });
}

/// `export_corpus` takes the version under the read guard: the exported
/// (rows, version) pair is a consistent cut in every schedule — never
/// version 1 with zero rows or version 0 with one row.
#[test]
fn export_is_a_consistent_cut() {
    model(|| {
        let ex = Arc::new(RetrievalExecutor::flat(2));
        let writer = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || ex.add(1, &[0.5, 0.5]))
        };
        let exporter = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || {
                if let Some((ids, rows, version)) = ex.export_corpus() {
                    assert_eq!(ids.len() as u64, version, "torn export cut");
                    assert_eq!(rows.len(), ids.len() * 2);
                }
            })
        };
        writer.join().unwrap();
        exporter.join().unwrap();
        let (ids, _, version) = ex.export_corpus().expect("flat index exports");
        assert_eq!(version, 1);
        assert_eq!(ids.len(), 1);
    });
}

/// A scan session opened mid-ingest pins a coherent corpus size: its
/// length is one of the two commit points, never a torn intermediate,
/// and the session does not block the writer from completing.
#[test]
fn scan_session_sees_committed_sizes_only() {
    model(|| {
        let ex = Arc::new(RetrievalExecutor::flat(2));
        ex.add(1, &[1.0, 0.0]);
        let writer = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || ex.add(2, &[0.0, 1.0]))
        };
        let scanner = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || {
                let session = ex.begin_scan();
                let len = session.len();
                assert!(len == 1 || len == 2, "torn corpus length: {len}");
            })
        };
        writer.join().unwrap();
        scanner.join().unwrap();
        assert_eq!(ex.len(), 2);
        assert_eq!(ex.version(), 2);
    });
}

/// The poisoned-lock recovery path: a manufactured `PoisonError` racing
/// a normal reader still yields the live corpus and bumps the
/// `poisoned_recoveries` counter exactly once.
#[test]
fn poisoned_recovery_counts_and_recovers() {
    model(|| {
        let ex = Arc::new(RetrievalExecutor::flat(2));
        ex.add(3, &[0.5, 0.5]);
        let probe = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || {
                // Recovery hands back the poisoned guard's data intact.
                assert_eq!(ex.poisoned_recovery_probe(), 1);
            })
        };
        let reader = {
            let ex = Arc::clone(&ex);
            thread::spawn(move || {
                // A concurrent plain reader is never disturbed by the
                // recovery happening next to it.
                assert_eq!(ex.len(), 1);
            })
        };
        probe.join().unwrap();
        reader.join().unwrap();
        assert_eq!(ex.poisoned_recoveries(), 1);
    });
}
