//! Loom models for the query-embedding LRU cache (PR 8): the stats
//! snapshot stays internally consistent under concurrent get/put, and
//! capacity is a hard bound in every schedule.

use crate::harness::model;
use loom::sync::Arc;
use loom::thread;
use windve::coordinator::cache::EmbeddingCache;

/// Two get-miss-then-fill threads on disjoint keys: every `get` is
/// counted as exactly one hit or one miss (never both, never dropped),
/// and the snapshot is a coherent cut of (hits, misses, len).
#[test]
fn snapshot_counts_every_get_once() {
    model(|| {
        let cache = Arc::new(EmbeddingCache::new(2));
        let handles: Vec<_> = (1..=2u64)
            .map(|key| {
                let cache = Arc::clone(&cache);
                thread::spawn(move || {
                    if cache.get(key).is_none() {
                        cache.put(key, vec![key as f32]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = cache.snapshot();
        // Disjoint keys nobody pre-filled: both gets are misses, both
        // fills land, nothing evicts.
        assert_eq!(stats.hits + stats.misses, 2, "a get was double- or un-counted");
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.capacity, 2);
        assert_eq!(cache.len(), 2);
    });
}

/// Two fills racing a capacity-1 cache: `len` never exceeds capacity,
/// and the entries not resident are accounted as evictions — inserts ==
/// residents + evictions in every interleaving.
#[test]
fn eviction_keeps_len_bounded() {
    model(|| {
        let cache = Arc::new(EmbeddingCache::new(1));
        let handles: Vec<_> = (1..=2u64)
            .map(|key| {
                let cache = Arc::clone(&cache);
                thread::spawn(move || {
                    cache.put(key, vec![key as f32]);
                    assert!(cache.len() <= 1, "capacity breached mid-race");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = cache.snapshot();
        assert_eq!(cache.len(), 1);
        assert_eq!(stats.evictions, 1, "2 inserts into capacity 1 evict exactly once");
    });
}

/// A hit racing a `reset_stats`: the final snapshot is one of the two
/// coherent outcomes (counted then cleared, or cleared then counted) —
/// never a torn mix, and never more events than gets issued.
#[test]
fn reset_stats_races_cleanly_with_hits() {
    model(|| {
        let cache = Arc::new(EmbeddingCache::new(2));
        cache.put(1, vec![1.0]);
        let getter = {
            let cache = Arc::clone(&cache);
            thread::spawn(move || {
                assert!(cache.get(1).is_some(), "resident key must hit");
            })
        };
        let resetter = {
            let cache = Arc::clone(&cache);
            thread::spawn(move || cache.reset_stats())
        };
        getter.join().unwrap();
        resetter.join().unwrap();
        let stats = cache.snapshot();
        // The single get either survived the reset or was wiped by it.
        assert!(stats.hits <= 1, "torn stats after reset: {} hits", stats.hits);
        assert_eq!(stats.misses, 0);
        assert_eq!(cache.len(), 1);
    });
}
