//! Connection-soak e2e for the readiness-loop server: hundreds of
//! concurrent keep-alive connections served by a handler pool at least
//! 16× smaller — connections cost file descriptors, not threads — with
//! every response byte-identical to the thread-per-connection server's
//! answer for the same request, and zero dropped or corrupted responses.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::{ServiceConfig, WindVE};
use windve::devices::executor::{Backend, SyntheticBackend};
use windve::devices::profile::DeviceProfile;
use windve::server::{Server, ServerOptions};
use windve::util::sys::raise_nofile_limit;

fn synth_factory(seed: u64) -> BackendFactory {
    Box::new(move || {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        Ok(Box::new(SyntheticBackend::new(p, 1e-6, seed)) as Box<dyn Backend>)
    })
}

/// NPU-only service with queue depth far above the connection count, so
/// admission never answers BUSY and every response is deterministic for
/// its text (synthetic embeddings are text-hash-derived; the only route
/// is "NPU").
fn start_service(depth: usize) -> Arc<WindVE> {
    Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: depth,
                cpu_depth: 0,
                hetero: false,
                npu_workers: 1,
                cpu_workers: 0,
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            vec![],
        )
        .unwrap(),
    )
}

fn soak_text(conn: usize, round: usize) -> String {
    // Many connections share texts (mod 97) so the sequential reference
    // pass stays short while every response is still byte-checked.
    format!("soak corpus query {} round {round}", conn % 97)
}

fn embed_request_bytes(text: &str, close: bool) -> Vec<u8> {
    let body = format!("{{\"texts\":[\"{text}\"]}}");
    let conn = if close { "Connection: close\r\n" } else { "" };
    format!(
        "POST /v1/embed HTTP/1.1\r\nHost: t\r\n{conn}Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Read exactly one HTTP response (head + Content-Length body) off a
/// keep-alive stream. Panics (→ test failure) on a closed or stalled
/// connection: a dropped response is exactly what the soak must catch.
fn read_one_response(stream: &mut TcpStream, who: &str) -> (u16, String, Vec<u8>) {
    let mut raw: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(p) = raw.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let n = stream.read(&mut chunk).unwrap_or_else(|e| panic!("{who}: read error {e}"));
        assert!(n > 0, "{who}: connection closed mid-response ({} bytes in)", raw.len());
        raw.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(raw[..head_end].to_vec()).unwrap();
    let clen: usize = head
        .lines()
        .find_map(|l| {
            l.to_ascii_lowercase()
                .strip_prefix("content-length:")
                .map(|v| v.trim().parse().unwrap())
        })
        .unwrap_or_else(|| panic!("{who}: no Content-Length in {head:?}"));
    let mut body = raw[head_end + 4..].to_vec();
    while body.len() < clen {
        let n = stream.read(&mut chunk).unwrap_or_else(|e| panic!("{who}: read error {e}"));
        assert!(n > 0, "{who}: connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(clen);
    let status: u16 = head.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    (status, head, body)
}

#[test]
fn soak_many_keepalive_connections_few_workers_bit_identical_to_threaded() {
    // FD budget: every connection costs two descriptors in this process
    // (client + server side). Scale to the headroom the host grants.
    let limit = raise_nofile_limit(4096);
    let conns = (512usize).min(((limit.saturating_sub(256)) / 2) as usize);
    assert!(conns >= 64, "fd limit {limit} leaves too little headroom to soak");
    let rounds = 3usize;
    // The decoupling under test: a handler pool ≥16× smaller than the
    // connection count (8 workers at the full 512 conns = 64×).
    let workers = (conns / 16).clamp(1, 8);

    // Reference pass: the thread-per-connection server answers each
    // distinct text sequentially; its bodies are the expected bytes.
    let reference: HashMap<String, Vec<u8>> = {
        let svc = start_service(4 * conns);
        let server = Server::start_threaded("127.0.0.1:0", svc, Duration::from_secs(2)).unwrap();
        let mut map = HashMap::new();
        for c in 0..conns {
            for r in 0..rounds {
                let text = soak_text(c, r);
                if map.contains_key(&text) {
                    continue;
                }
                let mut s = TcpStream::connect(server.addr()).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                s.write_all(&embed_request_bytes(&text, true)).unwrap();
                let (status, _, body) = read_one_response(&mut s, "reference");
                assert_eq!(status, 200, "reference {text:?}");
                map.insert(text, body);
            }
        }
        server.stop();
        map
    };

    // The soak: every connection holds keep-alive for all its rounds.
    let svc = start_service(4 * conns);
    let opts = ServerOptions {
        handler_workers: workers,
        ..ServerOptions::new(Duration::from_secs(2))
    };
    let server = Server::start_with_options("127.0.0.1:0", svc, opts).unwrap();
    let addr = server.addr();

    let clients: Vec<_> = (0..conns)
        .map(|c| {
            std::thread::Builder::new()
                .stack_size(128 * 1024)
                .spawn(move || {
                    // Stagger connects so the accept backlog never drops
                    // a SYN burst of hundreds at once.
                    std::thread::sleep(Duration::from_millis((c as u64 / 64) * 20));
                    let mut s = TcpStream::connect(addr)
                        .unwrap_or_else(|e| panic!("conn {c}: connect {e}"));
                    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                    let mut out: Vec<(String, Vec<u8>)> = Vec::with_capacity(rounds);
                    for r in 0..rounds {
                        let text = soak_text(c, r);
                        s.write_all(&embed_request_bytes(&text, false))
                            .unwrap_or_else(|e| panic!("conn {c} round {r}: write {e}"));
                        let (status, head, body) =
                            read_one_response(&mut s, &format!("conn {c} round {r}"));
                        assert_eq!(
                            status, 200,
                            "conn {c} round {r}: {}",
                            String::from_utf8_lossy(&body)
                        );
                        assert!(
                            head.to_ascii_lowercase().contains("connection: keep-alive"),
                            "conn {c} round {r}: {head}"
                        );
                        out.push((text, body));
                    }
                    out
                })
                .unwrap()
        })
        .collect();

    let mut served = 0usize;
    for (c, h) in clients.into_iter().enumerate() {
        for (text, body) in h.join().unwrap_or_else(|_| panic!("client {c} panicked")) {
            let want = reference.get(&text).unwrap_or_else(|| panic!("no reference for {text:?}"));
            assert_eq!(
                &body, want,
                "conn {c}: response for {text:?} differs from the threaded server"
            );
            served += 1;
        }
    }
    assert_eq!(served, conns * rounds, "every request must be answered");
    assert!(
        conns >= 16 * workers,
        "soak must hold ≥16× more connections ({conns}) than workers ({workers})"
    );
    server.stop();
}
