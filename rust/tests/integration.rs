//! Cross-module integration tests: estimator + sim + coordinator + cost
//! model composing the paper's §5.2 procedure end to end (no artifacts
//! needed — these run on calibrated profiles).

use windve::coordinator::queue_manager::{QueueManager, Route};
use windve::costmodel;
use windve::devices::profile::DeviceProfile;
use windve::estimator::{estimate_depth, fine_tune_depths, stress_search};
use windve::repro::{self, DevicePair};
use windve::sim::cluster::ClosedLoopSim;
use windve::sim::des::OpenLoopSim;
use windve::workload::diurnal::DiurnalCurve;

/// The full §5.2 pipeline: estimate → fine-tune → collaborative serving,
/// for every device pair and both SLOs.
#[test]
fn full_calibration_pipeline_all_pairs() {
    for pair in [
        DevicePair::v100_xeon_bge(),
        DevicePair::atlas_kunpeng_bge(),
        DevicePair::v100_xeon_jina(),
        DevicePair::atlas_kunpeng_jina(),
    ] {
        for slo in [1.0, 2.0] {
            let (npu_depth, cpu_depth) = repro::calibrate_pair(&pair, slo, 75, 99);
            assert!(npu_depth > 0, "{} must serve at {slo}s", pair.npu.name);
            // Joint validation through the production queue manager.
            let mut sim = ClosedLoopSim::new(
                pair.npu.clone(),
                Some(pair.cpu.clone()),
                npu_depth,
                cpu_depth,
                75,
                1,
            );
            sim.noisy = false;
            let joint = sim.max_concurrency(slo, 1, npu_depth + cpu_depth + 8, 1);
            assert_eq!(
                joint,
                npu_depth + cpu_depth,
                "{}+{} @{slo}s joint capacity",
                pair.npu.name,
                pair.cpu.name
            );
        }
    }
}

/// The theoretical §3.2 savings bound holds for every measured pair.
#[test]
fn savings_bound_respected_by_measurements() {
    for pair in [DevicePair::v100_xeon_bge(), DevicePair::atlas_kunpeng_bge()] {
        let slo = 1.0; // the bound's derivation assumes the α₁ regime
        let c_npu = pair.npu.true_max_concurrency(slo, 75);
        let c_cpu = pair.cpu.true_max_concurrency(slo, 75);
        // Ineq. 19: C_CPU/C_NPU < α_NPU/α_CPU (α measured in the same
        // low-concurrency regime the derivation uses).
        let bound = costmodel::concurrency_gain_bound(pair.npu.alpha1, pair.cpu.alpha1);
        let observed = c_cpu as f64 / c_npu as f64;
        assert!(
            observed <= bound + 0.05,
            "{}: observed {observed:.3} vs bound {bound:.3}",
            pair.npu.name
        );
    }
}

/// Estimator + stress + fine-tune agree within the stress step on clean
/// devices (the paper's Table 3 claim).
#[test]
fn estimator_stress_finetune_triangle() {
    let dev = DeviceProfile::v100_bge();
    let slo = 1.0;
    let mut sim1 = ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, 75, 5);
    let est = estimate_depth(slo, &[1, 2, 4, 8, 16, 24, 32], |c| sim1.measure_latency(c, 3));
    let mut sim2 = ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, 75, 6);
    let stress = stress_search(slo, 8, 256, |c| sim2.measure_latency(c, 3));
    let mut sim3 = ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, 75, 7);
    sim3.noisy = false;
    let tuned = fine_tune_depths(slo, est.predicted, 8, |c| sim3.measure_latency(c, 1));
    assert!(
        est.predicted.abs_diff(tuned) <= 8,
        "LR {} vs tuned {tuned}",
        est.predicted
    );
    assert!(
        stress.max_concurrency.abs_diff(tuned) <= 8,
        "stress {} vs tuned {tuned}",
        stress.max_concurrency
    );
    assert_eq!(tuned, 44);
}

/// Queue conservation under a simulated stretch of diurnal traffic.
#[test]
fn open_loop_day_replay_conserves_queries() {
    let curve = DiurnalCurve::typical(5.0, 4.0);
    let peak = curve.peak_rate();
    let arrivals = OpenLoopSim::poisson_arrivals(|h| curve.rate(h / 3600.0), peak, 600.0, 3);
    let sim = OpenLoopSim {
        npu: DeviceProfile::v100_bge(),
        cpu: Some(DeviceProfile::xeon_e5_2690_bge()),
        npu_depth: 44,
        cpu_depth: 8,
        qlen: 75,
        slo: 1.0,
        seed: 4,
    };
    let st = sim.run(&arrivals);
    assert_eq!(st.arrived as usize, arrivals.len());
    assert_eq!(st.served() + st.rejected, st.arrived);
    assert!(st.served() > 0);
}

/// Offloading strictly reduces rejects under a burst (the system claim).
#[test]
fn offloading_reduces_rejects_under_burst() {
    let burst: Vec<f64> = vec![0.0; 60];
    let mk = |cpu: Option<DeviceProfile>, cpu_depth: usize| OpenLoopSim {
        npu: DeviceProfile::v100_bge(),
        cpu,
        npu_depth: 44,
        cpu_depth,
        qlen: 75,
        slo: 1.0,
        seed: 5,
    };
    let b = mk(None, 0).run(&burst);
    let w = mk(Some(DeviceProfile::xeon_e5_2690_bge()), 8).run(&burst);
    assert!(w.rejected < b.rejected, "windve {} vs baseline {}", w.rejected, b.rejected);
    assert_eq!(b.rejected - w.rejected, 8, "CPU queue absorbs exactly its depth");
}

/// Algorithm 1 + Algorithm 2 compose: detector decision drives manager
/// construction.
#[test]
fn detector_decision_shapes_queue_manager() {
    use windve::coordinator::{detect, Inventory};
    // NPU + CPU, hetero on → two queues.
    let d = detect(Inventory { npus: 1, cpus: 1 }, true);
    let qm = QueueManager::new(4, 2, d.heter_enable);
    assert_eq!(qm.dispatch(), Route::Npu);
    for _ in 0..3 {
        qm.dispatch();
    }
    assert_eq!(qm.dispatch(), Route::Cpu);
    // CPU-only → hetero forced off; Algorithm 2 wins over the operator.
    let d = detect(Inventory { npus: 0, cpus: 1 }, true);
    assert!(!d.heter_enable);
    let qm = QueueManager::new(4, 2, d.heter_enable);
    for _ in 0..4 {
        assert_ne!(qm.dispatch(), Route::Cpu);
    }
    assert_eq!(qm.dispatch(), Route::Busy);
}

/// Fig. 5 / Fig. 6 / Table 1 are mutually consistent at their shared
/// anchor (75 tokens, 96 cores, 1 s SLO).
#[test]
fn cross_experiment_anchor_consistency() {
    let t1 = repro::table1::run(13);
    let f5 = repro::fig5::run(13);
    let f6 = repro::fig6::run(13);
    let t1_row = &t1[0]; // v100+xeon @1s
    let f5_pt = f5.iter().find(|p| p.slo == 1.0 && p.qlen == 75).unwrap();
    let f6_pt = f6.iter().find(|p| p.slo == 1.0 && p.cores == 96).unwrap();
    assert_eq!(t1_row.baseline, f5_pt.original);
    assert_eq!(t1_row.additional, f5_pt.additional);
    assert_eq!(t1_row.additional, f6_pt.additional);
}

/// Eq. 11: a CPU too slow for even one query is excluded by calibration.
#[test]
fn eq11_unusable_cpu_calibrates_to_zero() {
    let mut cpu = DeviceProfile::kunpeng_920_bge();
    cpu.beta = 1.5; // single query violates the 1 s SLO
    let pair = DevicePair { npu: DeviceProfile::atlas_300i_duo_bge(), cpu };
    let (npu_depth, cpu_depth) = repro::calibrate_pair(&pair, 1.0, 75, 21);
    assert!(npu_depth > 0);
    assert_eq!(cpu_depth, 0, "unusable CPU must get a zero-depth queue");
}
