//! Service-level end-to-end tests over synthetic backends (fast, no
//! artifacts): the paper's serving semantics through real threads.

use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::service::ServeError;
use windve::coordinator::{Route, ServiceConfig, WindVE};
use windve::devices::executor::{Backend, SyntheticBackend};
use windve::devices::profile::DeviceProfile;
use windve::testing::pseudo_embedding;

/// Synthetic factory at microsecond scale (ratios preserved).
fn synth_factory(profile: DeviceProfile, seed: u64) -> BackendFactory {
    Box::new(move || {
        let mut p = profile.clone();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        Ok(Box::new(SyntheticBackend::new(p, 1e-5, seed)) as Box<dyn Backend>)
    })
}

fn windve_service(npu_depth: usize, cpu_depth: usize, hetero: bool) -> WindVE {
    WindVE::start(
        ServiceConfig {
            npu_depth,
            cpu_depth,
            hetero,
            npu_workers: 1,
            cpu_workers: if hetero { 1 } else { 0 },
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
            ..ServiceConfig::default()
        },
        vec![synth_factory(DeviceProfile::v100_bge(), 1)],
        if hetero {
            vec![synth_factory(DeviceProfile::xeon_e5_2690_bge(), 2)]
        } else {
            vec![]
        },
    )
    .unwrap()
}

#[test]
fn sustained_closed_loop_traffic_all_served() {
    let svc = Arc::new(windve_service(44, 8, true));
    let mut handles = Vec::new();
    for t in 0..8 {
        let svc = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0u32;
            for i in 0..50 {
                match svc.embed_blocking(format!("{t}-{i} query text"), Duration::from_secs(10)) {
                    Ok(v) => {
                        assert!(!v.is_empty());
                        ok += 1;
                    }
                    Err(ServeError::Busy) => {}
                    Err(e) => panic!("unexpected: {e}"),
                }
            }
            ok
        }));
    }
    let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total >= 8 * 50 - 20, "served {total}");
    assert!(svc.queue_manager().stats().routed_npu > 0);
}

#[test]
fn peak_burst_spills_to_cpu_exactly_by_depth() {
    let svc = windve_service(4, 3, true);
    // Submit a burst of 10 without waiting: 4 NPU, 3 CPU, 3 busy.
    let mut routes = Vec::new();
    let mut tickets = Vec::new();
    for i in 0..10 {
        match svc.submit(format!("burst {i}")) {
            Ok(t) => {
                routes.push(t.route);
                tickets.push(t);
            }
            Err(ServeError::Busy) => routes.push(Route::Busy),
            Err(e) => panic!("{e}"),
        }
    }
    assert_eq!(routes.iter().filter(|r| **r == Route::Npu).count(), 4);
    assert_eq!(routes.iter().filter(|r| **r == Route::Cpu).count(), 3);
    assert_eq!(routes.iter().filter(|r| **r == Route::Busy).count(), 3);
    for t in tickets {
        t.wait(Duration::from_secs(10)).unwrap();
    }
    svc.shutdown();
}

#[test]
fn no_hetero_service_rejects_overflow_instead_of_cpu() {
    let svc = windve_service(4, 8, false);
    let mut busy = 0;
    let mut tickets = Vec::new();
    for i in 0..8 {
        match svc.submit(format!("q{i}")) {
            Ok(t) => {
                assert_eq!(t.route, Route::Npu);
                tickets.push(t);
            }
            Err(ServeError::Busy) => busy += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert_eq!(busy, 4);
    for t in tickets {
        t.wait(Duration::from_secs(10)).unwrap();
    }
}

#[test]
fn cpu_latency_exceeds_npu_latency_as_calibrated() {
    // β_CPU > β_NPU must be visible through the served latencies.
    let svc = windve_service(1, 1, true);
    let t_npu = svc.submit("to npu").unwrap();
    let t_cpu = svc.submit("to cpu").unwrap();
    assert_eq!(t_npu.route, Route::Npu);
    assert_eq!(t_cpu.route, Route::Cpu);
    let t0 = std::time::Instant::now();
    t_npu.wait(Duration::from_secs(10)).unwrap();
    let npu_el = t0.elapsed();
    t_cpu.wait(Duration::from_secs(10)).unwrap();
    let cpu_el = t0.elapsed();
    assert!(cpu_el >= npu_el, "CPU reply should not beat NPU reply");
    svc.shutdown();
}

#[test]
fn metrics_expose_per_route_latency() {
    let svc = windve_service(2, 2, true);
    for i in 0..4 {
        let _ = svc.embed_blocking(format!("m{i}"), Duration::from_secs(10));
    }
    let snap = svc.metrics.snapshot();
    let npu_hist = snap.get("service.e2e_npu_ns").expect("npu histogram present");
    assert!(npu_hist.get("count").and_then(|v| v.as_u64()).unwrap_or(0) > 0);
    assert_eq!(svc.metrics.counter("service.accepted").get(), 4);
}

#[test]
fn shutdown_drains_cleanly_under_load() {
    let svc = windve_service(16, 8, true);
    let mut tickets = Vec::new();
    for i in 0..12 {
        if let Ok(t) = svc.submit(format!("drain {i}")) {
            tickets.push(t);
        }
    }
    // Shutdown must complete (queues closed, workers joined) without
    // hanging even with queries in flight.
    svc.shutdown();
    // Replies either arrived before close or the channel disconnected.
    for t in tickets {
        match t.wait(Duration::from_secs(5)) {
            Ok(_) | Err(ServeError::Shutdown) | Err(ServeError::Backend(_)) => {}
            Err(e) => panic!("unexpected {e}"),
        }
    }
}

struct HashBackend {
    dim: usize,
}
impl Backend for HashBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        // A hair of service time so queue slots are genuinely held.
        std::thread::sleep(Duration::from_micros(200));
        Ok(texts.iter().map(|t| pseudo_embedding(t, self.dim)).collect())
    }
    fn describe(&self) -> String {
        "hash".into()
    }
    fn max_batch(&self) -> usize {
        16
    }
}

fn hash_factory(dim: usize) -> BackendFactory {
    Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))
}

/// Satellite: drive the service with retrieval + embed work past the
/// calibrated depth. Backpressure (`ServeError::Busy`) must come back
/// instead of unbounded queueing, and the per-class `QueueStats`
/// counters must reconcile with the completed work. The scan's slot
/// cost depends on the active codec's bytes_per_row, so the CI quant
/// matrix exercises admission at a different cost per cell.
#[test]
fn retrieval_saturation_returns_backpressure_and_reconciles() {
    use windve::coordinator::WorkClass;
    use windve::devices::executor::RetrievalExecutor;
    use windve::vecstore::Quant;

    let dim = 16;
    let quant = Quant::from_env();
    let unit = 1024; // 1 KiB cost unit so a 64-row corpus costs > 1 slot
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 8,
                hetero: true,
                retrieval_depth: Some(4),
                retrieval_cost_unit_bytes: unit,
                ..ServiceConfig::default()
            },
            vec![hash_factory(dim)],
            vec![hash_factory(dim)],
        )
        .unwrap(),
    );
    let exec = Arc::new(RetrievalExecutor::flat_quant(dim, quant));
    let docs: Vec<String> = (0..64).map(|i| format!("corpus doc {i}")).collect();
    for (i, d) in docs.iter().enumerate() {
        exec.add(i as u64, &pseudo_embedding(d, dim));
    }
    svc.attach_retrieval(Arc::clone(&exec));

    // Executor-reported cost follows the codec: ceil(64·bpr / 1KiB).
    let cost = exec.scan_cost(unit);
    assert_eq!(cost, (64 * quant.bytes_per_row(dim)).div_ceil(unit).max(1));
    assert!(cost <= 4, "cost {cost} must fit the retrieval cap");

    // Phase 1 (deterministic): hold the whole retrieval cap; a panel
    // must bounce with Busy immediately — backpressure, not a queue.
    let qm = svc.queue_manager();
    assert_eq!(qm.retrieve_cap(), 4);
    assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 4), windve::coordinator::Route::Cpu);
    let queries: Vec<String> = vec![docs[3].clone(), docs[40].clone(), docs[63].clone()];
    let t0 = std::time::Instant::now();
    let declined = svc.retrieve_blocking(&queries, 4, Duration::from_secs(10));
    assert!(t0.elapsed() < Duration::from_secs(5), "BUSY must not block");
    for r in &declined {
        assert_eq!(r.as_ref().unwrap_err(), &ServeError::Busy);
    }
    qm.release_class(WorkClass::Retrieve, windve::coordinator::Route::Cpu, 4);

    // Capacity restored: the same panel serves, with exact top hits.
    let served = svc.retrieve_blocking(&queries, 4, Duration::from_secs(10));
    for (q, r) in queries.iter().zip(&served) {
        let hits = r.as_ref().expect("retrieval failed after release");
        assert_eq!(hits, &exec.search(&pseudo_embedding(q, dim), 4));
    }

    // Phase 2: concurrent retrieve_blocking + submit callers past depth.
    let mut handles = Vec::new();
    for t in 0..6usize {
        let svc = Arc::clone(&svc);
        let docs = docs.clone();
        handles.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut busy = 0u64;
            for i in 0..15usize {
                let panel =
                    vec![docs[(t * 7 + i) % 64].clone(), docs[(t + 11 * i) % 64].clone()];
                for r in svc.retrieve_blocking(&panel, 3, Duration::from_secs(10)) {
                    match r {
                        Ok(hits) => {
                            assert_eq!(hits.len(), 3);
                            ok += 1;
                        }
                        Err(ServeError::Busy) => busy += 1,
                        Err(e) => panic!("unexpected error {e}"),
                    }
                }
            }
            (ok, busy)
        }));
    }
    for t in 0..3usize {
        let svc = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut busy = 0u64;
            for i in 0..30usize {
                match svc.embed_blocking(format!("embed {t}-{i}"), Duration::from_secs(10)) {
                    Ok(_) => ok += 1,
                    Err(ServeError::Busy) => busy += 1,
                    Err(e) => panic!("unexpected error {e}"),
                }
            }
            (ok, busy)
        }));
    }
    let mut ok_total = 0u64;
    for h in handles {
        ok_total += h.join().unwrap().0;
    }
    assert!(ok_total > 0);

    // Reconciliation: every admitted scan completed and released its
    // slots; per-class counters match the service-level metrics exactly.
    std::thread::sleep(Duration::from_millis(100));
    let st = qm.stats();
    let admitted = svc.metrics.counter("service.retrieve_admitted").get();
    // +1 for the manual cap hold in phase 1.
    assert_eq!(st.routed_retrieve, admitted + 1);
    assert_eq!(st.rejected_retrieve, svc.metrics.counter("service.retrieve_busy").get());
    assert_eq!(
        svc.metrics.counter("service.retrieve_cost_units").get(),
        admitted * cost as u64
    );
    assert_eq!(qm.retrieve_cpu_occupancy(), 0);
    assert_eq!(qm.embed_cpu_occupancy(), 0);
    assert_eq!(qm.cpu_occupancy(), 0);
    assert_eq!(qm.npu_occupancy(), 0);
    assert_eq!(st.bad_releases, 0);
}

/// Satellite: the seeded mixed embed+retrieve DES scenario reproduces
/// bit-for-bit, and enabling retrieval admission keeps the combined CPU
/// occupancy within the calibrated depth while the unaccounted baseline
/// demonstrably exceeds it (the PR's acceptance criterion).
#[test]
fn mixed_des_scenario_reproducible_and_bounded() {
    use windve::sim::{OpenLoopSim, RetrievalLoad};
    use windve::workload::MixedArrivals;

    fn quiet(mut p: DeviceProfile) -> DeviceProfile {
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        p
    }
    let sim = OpenLoopSim {
        npu: quiet(DeviceProfile::v100_bge()),
        cpu: Some(quiet(DeviceProfile::xeon_e5_2690_bge())),
        npu_depth: 4,
        cpu_depth: 8,
        qlen: 75,
        slo: 1.0,
        seed: 11,
    };
    let arr = MixedArrivals::poisson(60.0, 0.25, 10.0, 42);
    assert!(
        arr.observed_fraction() > 0.15 && arr.observed_fraction() < 0.35,
        "fraction {}",
        arr.observed_fraction()
    );
    let on = RetrievalLoad {
        cost: 4,
        service_time: 0.4,
        cap: 8,
        ..RetrievalLoad::default()
    };
    let a = sim.run_mixed(&on, &arr.embed, &arr.retrieve);
    let b = sim.run_mixed(&on, &arr.embed, &arr.retrieve);
    // Bit-for-bit reproducibility of the seeded scenario.
    assert_eq!(a.embed.reject_rate().to_bits(), b.embed.reject_rate().to_bits());
    assert_eq!(a.embed.slo_attainment().to_bits(), b.embed.slo_attainment().to_bits());
    assert_eq!(a.embed.arrived, b.embed.arrived);
    assert_eq!(a.retrieve_served, b.retrieve_served);
    assert_eq!(a.retrieve_rejected, b.retrieve_rejected);
    assert_eq!(a.retrieve_reject_rate().to_bits(), b.retrieve_reject_rate().to_bits());
    assert_eq!(a.peak_cpu_cost, b.peak_cpu_cost);
    assert_eq!(a.oversub_events, b.oversub_events);
    // Admission bounds the combined occupancy by the calibrated depth.
    assert!(a.peak_cpu_cost <= a.cpu_depth, "admitted peak {}", a.peak_cpu_cost);
    assert_eq!(a.oversub_events, 0);
    // The unaccounted baseline exceeds it under the same arrivals.
    let off = RetrievalLoad { admission: false, ..on.clone() };
    let c = sim.run_mixed(&off, &arr.embed, &arr.retrieve);
    assert!(c.peak_cpu_cost > c.cpu_depth, "baseline peak {}", c.peak_cpu_cost);
    assert!(c.oversub_events > a.oversub_events);
}

/// Tentpole acceptance (service side): with offload enabled the service
/// answers scans from the NPU leg with results bit-identical to the
/// offload-off CPU path, under real threads, and all occupancy drains.
#[test]
fn npu_offload_e2e_results_bit_identical_to_cpu_path() {
    use windve::devices::executor::RetrievalExecutor;

    let dim = 16;
    let mk = |npu_retrieval_depth: usize| {
        WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                npu_retrieval_depth,
                ..ServiceConfig::default()
            },
            vec![hash_factory(dim)],
            vec![hash_factory(dim)],
        )
        .unwrap()
    };
    let svc_off = mk(0); // CPU-only retrieval
    let svc_on = mk(4); // NPU offload leg enabled
    let docs: Vec<String> = (0..48).map(|i| format!("corpus doc {i}")).collect();
    let mk_exec = || {
        let exec = Arc::new(RetrievalExecutor::flat(dim));
        for (i, d) in docs.iter().enumerate() {
            exec.add(i as u64, &pseudo_embedding(d, dim));
        }
        exec
    };
    svc_off.attach_retrieval(mk_exec());
    svc_on.attach_retrieval(mk_exec());
    svc_on.mirror_retrieval_to_npu().unwrap();

    let queries: Vec<String> = vec![docs[3].clone(), docs[40].clone(), docs[17].clone()];
    let a = svc_on.retrieve_blocking(&queries, 5, Duration::from_secs(10));
    let b = svc_off.retrieve_blocking(&queries, 5, Duration::from_secs(10));
    for (x, y) in a.iter().zip(&b) {
        let (xa, ya) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        // Bit-identical hit lists: same ids, same order, same score bits.
        assert_eq!(xa, ya);
        for (ha, hb) in xa.iter().zip(ya) {
            assert_eq!(ha.score.to_bits(), hb.score.to_bits());
        }
    }
    // The on-service really used the device leg; the off-service didn't.
    assert_eq!(svc_on.queue_manager().stats().routed_retrieve_npu, 1);
    assert_eq!(svc_on.queue_manager().stats().routed_retrieve, 0);
    assert_eq!(svc_off.queue_manager().stats().routed_retrieve_npu, 0);
    assert_eq!(svc_on.metrics.counter("service.retrievals_npu").get(), 3);
    // Occupancy drains to zero on both legs.
    assert_eq!(svc_on.queue_manager().retrieve_npu_occupancy(), 0);
    assert_eq!(svc_on.queue_manager().npu_occupancy(), 0);
    assert_eq!(svc_on.queue_manager().stats().bad_releases, 0);
    svc_on.shutdown();
    svc_off.shutdown();
}

/// Tentpole acceptance (DES side): the seeded valley-burst scenario —
/// light embeds, a scan burst generated by `with_scan_burst` — shows the
/// NPU leg strictly raising admitted concurrency over CPU-only admission
/// at zero oversubscription, bit-for-bit reproducibly.
#[test]
fn npu_offload_des_scenario_strictly_beats_cpu_only_admission() {
    use windve::sim::{OpenLoopSim, RetrievalLoad};
    use windve::workload::MixedArrivals;

    fn quiet(mut p: DeviceProfile) -> DeviceProfile {
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        p
    }
    let sim = OpenLoopSim {
        npu: quiet(DeviceProfile::v100_bge()),
        cpu: Some(quiet(DeviceProfile::xeon_e5_2690_bge())),
        npu_depth: 44,
        cpu_depth: 8,
        qlen: 75,
        slo: 1.0,
        seed: 23,
    };
    // An embedding valley (2 q/s) with a dense 3-second scan burst.
    let arr = MixedArrivals::poisson(2.0, 0.0, 10.0, 31).with_scan_burst(1.0, 3.0, 15.0, 32);
    assert!(arr.retrieve.len() > 20, "burst too thin: {}", arr.retrieve.len());
    let load = |npu_cap: usize| RetrievalLoad {
        cost: 4,
        service_time: 0.6,
        cap: 8,
        npu_cap,
        ..RetrievalLoad::default()
    };
    let cpu_only = sim.run_mixed(&load(0), &arr.embed, &arr.retrieve);
    let offload = sim.run_mixed(&load(16), &arr.embed, &arr.retrieve);
    // Equal oversubscription: zero events either way.
    assert_eq!(cpu_only.oversub_events, 0);
    assert_eq!(offload.oversub_events, 0);
    // Strictly more admitted concurrency and served scans with the leg.
    assert!(
        offload.peak_admitted_cost > cpu_only.peak_admitted_cost,
        "peak {} vs {}",
        offload.peak_admitted_cost,
        cpu_only.peak_admitted_cost
    );
    assert!(
        offload.retrieve_served > cpu_only.retrieve_served,
        "served {} vs {}",
        offload.retrieve_served,
        cpu_only.retrieve_served
    );
    assert!(offload.retrieve_served_npu > 0);
    assert!(offload.peak_npu_cost <= offload.npu_depth);
    // Bit-for-bit reproducible.
    let again = sim.run_mixed(&load(16), &arr.embed, &arr.retrieve);
    assert_eq!(again.retrieve_served, offload.retrieve_served);
    assert_eq!(again.retrieve_served_npu, offload.retrieve_served_npu);
    assert_eq!(again.peak_admitted_cost, offload.peak_admitted_cost);
    assert_eq!(
        again.embed.slo_attainment().to_bits(),
        offload.embed.slo_attainment().to_bits()
    );
}

#[test]
fn cache_serves_repeats_without_queue_slots() {
    // Depth 1 + cache: the first query fills the cache; repeats must be
    // served even while the single slot is held by another query.
    let svc = WindVE::start(
        ServiceConfig {
            npu_depth: 1,
            cpu_depth: 0,
            hetero: false,
            npu_workers: 1,
            cpu_workers: 0,
            cpu_pin_cores: None,
            cache_entries: 64,
            cache_key_space: (8192, 128),
            ..ServiceConfig::default()
        },
        vec![synth_factory(DeviceProfile::v100_bge(), 3)],
        vec![],
    )
    .unwrap();
    let v1 = svc.embed_blocking("popular query", Duration::from_secs(10)).unwrap();
    // Hold the only slot.
    let _holder = svc.submit("slot holder").unwrap();
    assert_eq!(svc.submit("anything else").unwrap_err(), ServeError::Busy);
    // The cached repeat still succeeds, identical vector, no queue slot.
    let v2 = svc.embed_blocking("popular query", Duration::from_secs(1)).unwrap();
    assert_eq!(v1, v2);
    assert_eq!(svc.metrics.counter("service.cache_hits").get(), 1);
    // Token-normalised variant hits the same entry.
    let v3 = svc.embed_blocking("POPULAR, query!", Duration::from_secs(1)).unwrap();
    assert_eq!(v1, v3);
}
