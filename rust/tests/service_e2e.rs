//! Service-level end-to-end tests over synthetic backends (fast, no
//! artifacts): the paper's serving semantics through real threads.

use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::service::ServeError;
use windve::coordinator::{Route, ServiceConfig, WindVE};
use windve::devices::executor::{Backend, SyntheticBackend};
use windve::devices::profile::DeviceProfile;

/// Synthetic factory at microsecond scale (ratios preserved).
fn synth_factory(profile: DeviceProfile, seed: u64) -> BackendFactory {
    Box::new(move || {
        let mut p = profile.clone();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        Ok(Box::new(SyntheticBackend::new(p, 1e-5, seed)) as Box<dyn Backend>)
    })
}

fn windve_service(npu_depth: usize, cpu_depth: usize, hetero: bool) -> WindVE {
    WindVE::start(
        ServiceConfig {
            npu_depth,
            cpu_depth,
            hetero,
            npu_workers: 1,
            cpu_workers: if hetero { 1 } else { 0 },
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
        },
        vec![synth_factory(DeviceProfile::v100_bge(), 1)],
        if hetero {
            vec![synth_factory(DeviceProfile::xeon_e5_2690_bge(), 2)]
        } else {
            vec![]
        },
    )
    .unwrap()
}

#[test]
fn sustained_closed_loop_traffic_all_served() {
    let svc = Arc::new(windve_service(44, 8, true));
    let mut handles = Vec::new();
    for t in 0..8 {
        let svc = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0u32;
            for i in 0..50 {
                match svc.embed_blocking(format!("{t}-{i} query text"), Duration::from_secs(10)) {
                    Ok(v) => {
                        assert!(!v.is_empty());
                        ok += 1;
                    }
                    Err(ServeError::Busy) => {}
                    Err(e) => panic!("unexpected: {e}"),
                }
            }
            ok
        }));
    }
    let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total >= 8 * 50 - 20, "served {total}");
    assert!(svc.queue_manager().stats().routed_npu > 0);
}

#[test]
fn peak_burst_spills_to_cpu_exactly_by_depth() {
    let svc = windve_service(4, 3, true);
    // Submit a burst of 10 without waiting: 4 NPU, 3 CPU, 3 busy.
    let mut routes = Vec::new();
    let mut tickets = Vec::new();
    for i in 0..10 {
        match svc.submit(format!("burst {i}")) {
            Ok(t) => {
                routes.push(t.route);
                tickets.push(t);
            }
            Err(ServeError::Busy) => routes.push(Route::Busy),
            Err(e) => panic!("{e}"),
        }
    }
    assert_eq!(routes.iter().filter(|r| **r == Route::Npu).count(), 4);
    assert_eq!(routes.iter().filter(|r| **r == Route::Cpu).count(), 3);
    assert_eq!(routes.iter().filter(|r| **r == Route::Busy).count(), 3);
    for t in tickets {
        t.wait(Duration::from_secs(10)).unwrap();
    }
    svc.shutdown();
}

#[test]
fn no_hetero_service_rejects_overflow_instead_of_cpu() {
    let svc = windve_service(4, 8, false);
    let mut busy = 0;
    let mut tickets = Vec::new();
    for i in 0..8 {
        match svc.submit(format!("q{i}")) {
            Ok(t) => {
                assert_eq!(t.route, Route::Npu);
                tickets.push(t);
            }
            Err(ServeError::Busy) => busy += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert_eq!(busy, 4);
    for t in tickets {
        t.wait(Duration::from_secs(10)).unwrap();
    }
}

#[test]
fn cpu_latency_exceeds_npu_latency_as_calibrated() {
    // β_CPU > β_NPU must be visible through the served latencies.
    let svc = windve_service(1, 1, true);
    let t_npu = svc.submit("to npu").unwrap();
    let t_cpu = svc.submit("to cpu").unwrap();
    assert_eq!(t_npu.route, Route::Npu);
    assert_eq!(t_cpu.route, Route::Cpu);
    let t0 = std::time::Instant::now();
    t_npu.wait(Duration::from_secs(10)).unwrap();
    let npu_el = t0.elapsed();
    t_cpu.wait(Duration::from_secs(10)).unwrap();
    let cpu_el = t0.elapsed();
    assert!(cpu_el >= npu_el, "CPU reply should not beat NPU reply");
    svc.shutdown();
}

#[test]
fn metrics_expose_per_route_latency() {
    let svc = windve_service(2, 2, true);
    for i in 0..4 {
        let _ = svc.embed_blocking(format!("m{i}"), Duration::from_secs(10));
    }
    let snap = svc.metrics.snapshot();
    let npu_hist = snap.get("service.e2e_npu_ns").expect("npu histogram present");
    assert!(npu_hist.get("count").and_then(|v| v.as_u64()).unwrap_or(0) > 0);
    assert_eq!(svc.metrics.counter("service.accepted").get(), 4);
}

#[test]
fn shutdown_drains_cleanly_under_load() {
    let svc = windve_service(16, 8, true);
    let mut tickets = Vec::new();
    for i in 0..12 {
        if let Ok(t) = svc.submit(format!("drain {i}")) {
            tickets.push(t);
        }
    }
    // Shutdown must complete (queues closed, workers joined) without
    // hanging even with queries in flight.
    svc.shutdown();
    // Replies either arrived before close or the channel disconnected.
    for t in tickets {
        match t.wait(Duration::from_secs(5)) {
            Ok(_) | Err(ServeError::Shutdown) | Err(ServeError::Backend(_)) => {}
            Err(e) => panic!("unexpected {e}"),
        }
    }
}

#[test]
fn cache_serves_repeats_without_queue_slots() {
    // Depth 1 + cache: the first query fills the cache; repeats must be
    // served even while the single slot is held by another query.
    let svc = WindVE::start(
        ServiceConfig {
            npu_depth: 1,
            cpu_depth: 0,
            hetero: false,
            npu_workers: 1,
            cpu_workers: 0,
            cpu_pin_cores: None,
            cache_entries: 64,
            cache_key_space: (8192, 128),
        },
        vec![synth_factory(DeviceProfile::v100_bge(), 3)],
        vec![],
    )
    .unwrap();
    let v1 = svc.embed_blocking("popular query", Duration::from_secs(10)).unwrap();
    // Hold the only slot.
    let _holder = svc.submit("slot holder").unwrap();
    assert_eq!(svc.submit("anything else").unwrap_err(), ServeError::Busy);
    // The cached repeat still succeeds, identical vector, no queue slot.
    let v2 = svc.embed_blocking("popular query", Duration::from_secs(1)).unwrap();
    assert_eq!(v1, v2);
    assert_eq!(svc.metrics.counter("service.cache_hits").get(), 1);
    // Token-normalised variant hits the same entry.
    let v3 = svc.embed_blocking("POPULAR, query!", Duration::from_secs(1)).unwrap();
    assert_eq!(v1, v3);
}
