//! Failure-injection tests: worker faults, poisoned backends, BUSY
//! storms, slot-leak detection — the service must degrade, not wedge.
//!
//! The second half is the durable-corpus kill-point matrix: every
//! mutating fs op of a fixed lifecycle (ingest commits, deletes, a
//! snapshot, a compaction) becomes a crash point, and after each crash
//! recovery must land on a consistent prefix of the submitted history —
//! no acked write lost, no delete resurrected, replayed rows
//! bit-identical. Deterministic companions pin the non-crash faults
//! (fsync EIO, short writes) whose semantics the crash matrix can't
//! express; `prop_durability_replay_is_acked_prefix` in `proptests.rs`
//! is the randomized version.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::service::ServeError;
use windve::coordinator::{ServiceConfig, WindVE};
use windve::devices::executor::Backend;

/// Backend that panics every `nth` batch.
struct FlakyBackend {
    calls: usize,
    nth: usize,
}

impl Backend for FlakyBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.calls += 1;
        if self.calls % self.nth == 0 {
            panic!("injected fault on batch {}", self.calls);
        }
        Ok(texts.iter().map(|_| vec![1.0]).collect())
    }
    fn describe(&self) -> String {
        "flaky".into()
    }
    fn max_batch(&self) -> usize {
        4
    }
}

/// Backend that errors (not panics) on odd batches.
struct ErroringBackend {
    calls: AtomicUsize,
}

impl Backend for ErroringBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        if self.calls.fetch_add(1, Ordering::Relaxed) % 2 == 0 {
            anyhow::bail!("transient device error");
        }
        Ok(texts.iter().map(|_| vec![2.0]).collect())
    }
    fn describe(&self) -> String {
        "erroring".into()
    }
    fn max_batch(&self) -> usize {
        2
    }
}

/// Backend that returns the wrong number of vectors.
struct ShortBackend;

impl Backend for ShortBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(texts.iter().skip(1).map(|_| vec![3.0]).collect())
    }
    fn describe(&self) -> String {
        "short".into()
    }
    fn max_batch(&self) -> usize {
        8
    }
}

fn service_with(factory: BackendFactory, depth: usize) -> WindVE {
    WindVE::start(
        ServiceConfig {
            npu_depth: depth,
            cpu_depth: 0,
            hetero: false,
            npu_workers: 1,
            cpu_workers: 0,
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
            ..ServiceConfig::default()
        },
        vec![factory],
        vec![],
    )
    .unwrap()
}

#[test]
fn panicking_backend_never_wedges_service() {
    let svc = service_with(
        Box::new(|| Ok(Box::new(FlakyBackend { calls: 0, nth: 3 }) as Box<dyn Backend>)),
        64,
    );
    let mut ok = 0;
    let mut failed = 0;
    for i in 0..60 {
        match svc.embed_blocking(format!("q{i}"), Duration::from_secs(10)) {
            Ok(_) => ok += 1,
            Err(ServeError::Backend(msg)) => {
                assert!(msg.contains("injected fault"), "{msg}");
                failed += 1;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(ok > 0, "some queries must survive");
    assert!(failed > 0, "injected faults must surface as Backend errors");
    // No slots leaked despite the panics.
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
    svc.shutdown();
}

#[test]
fn erroring_backend_reports_and_recovers() {
    let svc = service_with(
        Box::new(|| {
            Ok(Box::new(ErroringBackend { calls: AtomicUsize::new(0) }) as Box<dyn Backend>)
        }),
        64,
    );
    let mut saw_error = false;
    let mut saw_ok = false;
    for i in 0..20 {
        match svc.embed_blocking(format!("q{i}"), Duration::from_secs(10)) {
            Ok(_) => saw_ok = true,
            Err(ServeError::Backend(m)) => {
                assert!(m.contains("transient device error"));
                saw_error = true;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(saw_error && saw_ok);
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

#[test]
fn wrong_arity_backend_fails_batch_safely() {
    let svc = service_with(Box::new(|| Ok(Box::new(ShortBackend) as Box<dyn Backend>)), 64);
    let err = svc
        .embed_blocking("only query", Duration::from_secs(10))
        .unwrap_err();
    match err {
        ServeError::Backend(m) => assert!(m.contains("vectors"), "{m}"),
        e => panic!("unexpected {e}"),
    }
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

#[test]
fn busy_storm_recovers_after_drain() {
    // Slow backend + tiny queue: hammer it, collect BUSYs, then verify
    // the service is fully usable afterwards.
    struct SlowBackend;
    impl Backend for SlowBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            std::thread::sleep(Duration::from_millis(30));
            Ok(texts.iter().map(|_| vec![1.0]).collect())
        }
        fn describe(&self) -> String {
            "slow".into()
        }
        fn max_batch(&self) -> usize {
            2
        }
    }
    let svc = Arc::new(service_with(
        Box::new(|| Ok(Box::new(SlowBackend) as Box<dyn Backend>)),
        2,
    ));
    let mut busy = 0;
    let mut tickets = Vec::new();
    for i in 0..50 {
        match svc.submit(format!("storm {i}")) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Busy) => busy += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert!(busy >= 40, "storm should mostly reject (busy={busy})");
    for t in tickets {
        t.wait(Duration::from_secs(10)).unwrap();
    }
    // Recovered: a fresh query goes straight through.
    assert!(svc.embed_blocking("after storm", Duration::from_secs(10)).is_ok());
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

#[test]
fn failed_backend_init_degrades_to_errors_not_hangs() {
    let svc = service_with(Box::new(|| anyhow::bail!("artifacts missing")), 8);
    for i in 0..5 {
        let err = svc
            .embed_blocking(format!("doomed {i}"), Duration::from_secs(10))
            .unwrap_err();
        match err {
            ServeError::Backend(m) => assert!(m.contains("backend init failed"), "{m}"),
            e => panic!("unexpected {e}"),
        }
    }
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

// ---------------------------------------------------------------------------
// Durable corpus lifecycle: crash matrix and non-crash faults.

mod durable {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Arc;

    use windve::devices::executor::RetrievalExecutor;
    use windve::durability::{
        DurabilityOptions, DurableStore, FaultFs, FaultPlan, Fs, RecoveryReport,
    };
    use windve::testing::pseudo_embedding;
    use windve::vecstore::FlatIndex;

    const DIM: usize = 8;

    fn recover(
        fs: &Arc<FaultFs>,
        opts: &DurabilityOptions,
    ) -> Result<(Arc<DurableStore>, Arc<RetrievalExecutor>, RecoveryReport), anyhow::Error> {
        let dynfs: Arc<dyn Fs> = fs.clone();
        DurableStore::recover(
            dynfs,
            Path::new("/store"),
            opts.clone(),
            || Box::new(FlatIndex::new(DIM)),
            |text| Ok(pseudo_embedding(text, DIM)),
        )
    }

    fn commit(store: &DurableStore, exec: &RetrievalExecutor, id: u64, text: &str) -> bool {
        let v = pseudo_embedding(text, DIM);
        store
            .log_upserts(&[(id, text)], || {
                exec.upsert_batch(&[(id, v)]);
            })
            .is_ok()
    }

    fn delete(store: &DurableStore, exec: &RetrievalExecutor, id: u64) -> bool {
        store
            .log_delete(id, || {
                exec.remove(id);
            })
            .is_ok()
    }

    /// Live corpus as an id → embedding-bits map; fails on duplicate ids.
    fn corpus_map(exec: &RetrievalExecutor) -> HashMap<u64, Vec<u32>> {
        let (ids, rows, _version) =
            exec.export_corpus().expect("flat index exports its corpus");
        let mut map = HashMap::new();
        for (row, id) in ids.iter().enumerate() {
            let bits: Vec<u32> =
                rows[row * DIM..(row + 1) * DIM].iter().map(|x| x.to_bits()).collect();
            assert!(map.insert(*id, bits).is_none(), "duplicate id {id} in export");
        }
        map
    }

    fn expect_state(got: &HashMap<u64, Vec<u32>>, want: &HashMap<u64, String>, ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: live doc count");
        for (id, text) in want {
            let bits: Vec<u32> =
                pseudo_embedding(text, DIM).iter().map(|x| x.to_bits()).collect();
            match got.get(id) {
                None => panic!("{ctx}: acked doc {id} lost"),
                Some(r) => assert_eq!(r, &bits, "{ctx}: doc {id} replayed with different bits"),
            }
        }
    }

    /// One scripted action. `Up`/`Del` consume one WAL sequence each;
    /// `Snap`/`Compact` move checkpoints but no sequences — so the
    /// committed sequence after recovery indexes directly into the
    /// prefix-state table.
    #[derive(Clone, Copy)]
    enum Act {
        Up(u64, &'static str),
        Del(u64),
        Snap,
        Compact,
    }

    /// A fixed lifecycle covering every window the contract names:
    /// commits before and after a snapshot, a delete whose tombstone the
    /// snapshot captures, an overwrite of a deleted id, enough
    /// tombstones to trip compaction, and a commit after the compaction.
    const SCRIPT: &[Act] = &[
        Act::Up(1, "alpha"),
        Act::Up(2, "bravo"),
        Act::Up(3, "charlie"),
        Act::Del(2),
        Act::Snap,
        Act::Up(4, "delta"),
        Act::Up(2, "bravo rewritten"),
        Act::Del(1),
        Act::Del(3),
        Act::Compact,
        Act::Up(5, "echo"),
    ];

    /// Corpus content after each WAL sequence (`states[j]` = after `j`
    /// mutations); checkpoints don't change content so add no entries.
    fn prefix_states() -> Vec<HashMap<u64, String>> {
        let mut states: Vec<HashMap<u64, String>> = vec![HashMap::new()];
        for act in SCRIPT {
            let mut next = states.last().unwrap().clone();
            match act {
                Act::Up(id, text) => {
                    next.insert(*id, text.to_string());
                }
                Act::Del(id) => {
                    next.remove(id);
                }
                Act::Snap | Act::Compact => continue,
            }
            states.push(next);
        }
        states
    }

    /// Drive the script until the first refused action; returns
    /// mutations acked. Snapshot/compaction failures also stop the run —
    /// under a crash-only plan an error means the machine is down.
    fn drive(store: &DurableStore, exec: &RetrievalExecutor) -> usize {
        let mut acked = 0usize;
        for act in SCRIPT {
            let ok = match act {
                Act::Up(id, text) => commit(store, exec, *id, text),
                Act::Del(id) => delete(store, exec, *id),
                Act::Snap => store.snapshot(exec).is_ok(),
                Act::Compact => store.maybe_compact(exec).is_ok(),
            };
            if !ok {
                return acked;
            }
            if matches!(act, Act::Up(..) | Act::Del(..)) {
                acked += 1;
            }
        }
        acked
    }

    /// Sweep a crash into every mutating fs op of the lifecycle — WAL
    /// appends and fsyncs, the snapshot's atomic write, the WAL
    /// truncation behind it, and the compaction checkpoint — and require
    /// recovery to land on `states[j]` with `j` covering every acked
    /// mutation (at most one past it when a torn tail keeps the
    /// in-flight record whole).
    #[test]
    fn kill_point_matrix_recovers_a_consistent_prefix() {
        // Small segments so the snapshot actually truncates multiple
        // files and a crash can land between per-segment removals.
        let opts = DurabilityOptions { segment_bytes: 48, compact_tombstone_ratio: 0.3 };
        let states = prefix_states();

        // Fault-free run sizes the kill-point space (recovery of an
        // empty store performs no mutating fs ops).
        let fs = Arc::new(FaultFs::new());
        let (store, exec, _) = recover(&fs, &opts).unwrap();
        assert_eq!(drive(&store, &exec), states.len() - 1, "fault-free run acks everything");
        let total = fs.ops();
        assert!(total > 20, "scenario too small to be interesting: {total} ops");

        for kill in 0..total {
            // torn_keep 64 keeps any single in-flight record intact,
            // exercising the logged-but-unacked replay arm.
            for torn in [0usize, 5, 64] {
                let ctx = format!("kill at op {kill}/{total}, torn_keep {torn}");
                let fs = Arc::new(FaultFs::with_plan(FaultPlan {
                    crash_at_op: Some(kill),
                    torn_keep: torn,
                    ..Default::default()
                }));
                let (store, exec, _) = recover(&fs, &opts).unwrap();
                let acked = drive(&store, &exec);
                assert!(acked < states.len(), "{ctx}: crash never fired");
                fs.restart(FaultPlan::default());
                let (store2, exec2, report) = recover(&fs, &opts)
                    .unwrap_or_else(|e| panic!("{ctx}: recovery failed: {e}"));
                let j = store2.stats().committed_seq as usize;
                assert!(
                    j == acked || j == acked + 1,
                    "{ctx}: recovered prefix {j} outside [{acked}, {}]",
                    acked + 1
                );
                assert_eq!(
                    report.watermark + report.replayed,
                    j as u64,
                    "{ctx}: snapshot + tail must cover the committed sequence"
                );
                expect_state(&corpus_map(&exec2), &states[j], &ctx);
                // The store stays writable after recovery.
                assert!(commit(&store2, &exec2, 99, "post recovery"), "{ctx}: store wedged");
            }
        }
    }

    /// An fsync EIO refuses the ack and leaves the index clean — but the
    /// appended bytes sit in the page cache, and a LATER successful
    /// fsync makes them durable. Replay may therefore include the
    /// refused record: the contract's weak converse (logged-but-unacked
    /// records replay in submitted order, never a reordering).
    #[test]
    fn fsync_error_refuses_ack_but_record_may_replay_after_later_sync() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions { compact_tombstone_ratio: 0.0, ..Default::default() };
        // Ops: 0 = append "refused", 1 = its fsync (EIO).
        fs.restart(FaultPlan { fsync_fail_at: Some(1), ..Default::default() });
        let (store, exec, _) = recover(&fs, &opts).unwrap();
        assert!(!commit(&store, &exec, 1, "refused"), "fsync EIO must refuse the ack");
        assert_eq!(exec.len(), 0, "index untouched on a refused commit");
        assert_eq!(store.stats().wal_append_failures, 1);
        // The next commit's fsync flushes the whole file — including the
        // refused record sitting ahead of it.
        assert!(commit(&store, &exec, 2, "acked"));
        assert_eq!(exec.len(), 1, "only the acked doc is live in-process");

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (store2, exec2, report) = recover(&fs, &opts).unwrap();
        assert_eq!(report.replayed, 2, "refused record replays ahead of the acked one");
        assert_eq!(store2.stats().committed_seq, 2);
        let got = corpus_map(&exec2);
        assert!(got.contains_key(&2), "acked doc must survive");
        assert!(got.contains_key(&1), "logged-but-unacked doc replays (prefix extension)");
    }

    /// A short write mid-ingest refuses that ack, and the WAL's tail
    /// repair keeps every LATER acked record replayable — the partial
    /// bytes must not become a torn region entombing the rest of the log.
    #[test]
    fn short_write_mid_ingest_preserves_later_acked_records() {
        let fs = Arc::new(FaultFs::with_plan(FaultPlan {
            short_write_at: Some(2),
            ..Default::default()
        }));
        let opts = DurabilityOptions { compact_tombstone_ratio: 0.0, ..Default::default() };
        let (store, exec, _) = recover(&fs, &opts).unwrap();
        assert!(commit(&store, &exec, 1, "before the fault")); // ops 0-1
        assert!(!commit(&store, &exec, 2, "short-written"), "short write refuses the ack");
        assert_eq!(store.stats().wal_append_failures, 1);
        assert!(commit(&store, &exec, 3, "after the repair"), "store keeps working");

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (store2, exec2, report) = recover(&fs, &opts).unwrap();
        assert_eq!(report.replayed, 2);
        assert_eq!(store2.stats().committed_seq, 2, "refused record consumed no sequence");
        let got = corpus_map(&exec2);
        assert!(got.contains_key(&1) && got.contains_key(&3), "both acked docs survive");
        assert!(!got.contains_key(&2), "refused doc stays refused");
    }

    /// Crash between the WAL fsync and the index commit: the record is
    /// durable but the index never absorbed it. Replay must re-apply it.
    #[test]
    fn crash_between_wal_fsync_and_index_commit_replays_the_record() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions::default();
        let (store, exec, _) = recover(&fs, &opts).unwrap();
        assert!(commit(&store, &exec, 1, "fully committed"));
        // The commit closure is where the index mutation runs; an empty
        // one models the process dying right after the fsync returned.
        store.log_upserts(&[(2, "logged, never indexed")], || {}).unwrap();
        assert_eq!(exec.len(), 1, "index never saw doc 2");

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (store2, exec2, report) = recover(&fs, &opts).unwrap();
        assert_eq!(report.replayed, 2);
        assert_eq!(store2.stats().committed_seq, 2);
        let got = corpus_map(&exec2);
        assert_eq!(got.len(), 2);
        assert!(got.contains_key(&2), "durable-but-unindexed record must replay");
    }

    /// Deleted ids stay deleted across snapshot, crash, and replay —
    /// whether the tombstone is inside the snapshot or in the tail.
    #[test]
    fn deleted_ids_never_resurrect_across_crash_and_snapshot() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions { compact_tombstone_ratio: 0.0, ..Default::default() };
        let (store, exec, _) = recover(&fs, &opts).unwrap();
        for (id, text) in [(1, "one"), (2, "two"), (3, "three"), (4, "four")] {
            assert!(commit(&store, &exec, id, text));
        }
        assert!(delete(&store, &exec, 2)); // tombstone captured by the snapshot
        store.snapshot(&exec).unwrap();
        assert!(delete(&store, &exec, 3)); // tombstone only in the WAL tail

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (_, exec2, report) = recover(&fs, &opts).unwrap();
        assert!(report.from_snapshot);
        assert_eq!(report.replayed, 1, "only the post-snapshot delete replays");
        let got = corpus_map(&exec2);
        assert_eq!(got.len(), 2);
        assert!(!got.contains_key(&2) && !got.contains_key(&3), "deleted ids resurrected");
        // Searches agree: the deleted ids never rank.
        for id in [2u64, 3] {
            let q = pseudo_embedding(if id == 2 { "two" } else { "three" }, DIM);
            assert!(exec2.search(&q, 4).iter().all(|h| h.id != id), "id {id} still searchable");
        }
    }

    /// Release-mode CI smoke: a moderately sized ingest → delete →
    /// snapshot → ingest lifecycle, one hard crash, full recovery with
    /// bit-identical scores.
    /// (`cargo test --release --test failure_injection crash_replay`.)
    #[test]
    fn crash_replay_smoke() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions { segment_bytes: 512, compact_tombstone_ratio: 0.0 };
        let (store, exec, _) = recover(&fs, &opts).unwrap();
        for i in 0..40u64 {
            assert!(commit(&store, &exec, i, &format!("smoke doc number {i}")));
        }
        for i in (0..40u64).step_by(4) {
            assert!(delete(&store, &exec, i)); // 10 deletes
        }
        store.snapshot(&exec).unwrap();
        for i in 40..50u64 {
            assert!(commit(&store, &exec, i, &format!("smoke doc number {i}")));
        }
        assert!(delete(&store, &exec, 41));
        assert!(delete(&store, &exec, 43));
        let probes: Vec<Vec<f32>> = (0..5)
            .map(|i| pseudo_embedding(&format!("smoke doc number {}", i * 7 + 1), DIM))
            .collect();
        let want: Vec<Vec<(u64, u32)>> = probes
            .iter()
            .map(|q| exec.search(q, 8).iter().map(|h| (h.id, h.score.to_bits())).collect())
            .collect();

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (store2, exec2, report) = recover(&fs, &opts).unwrap();
        assert!(report.from_snapshot);
        assert_eq!(report.replayed, 12, "10 post-snapshot upserts + 2 deletes");
        assert_eq!(store2.stats().committed_seq, 62);
        assert_eq!(exec2.len(), 38, "40 - 10 deleted + 10 new - 2 deleted");
        let got: Vec<Vec<(u64, u32)>> = probes
            .iter()
            .map(|q| exec2.search(q, 8).iter().map(|h| (h.id, h.score.to_bits())).collect())
            .collect();
        assert_eq!(got, want, "recovered index scores bit-identically");
    }
}
