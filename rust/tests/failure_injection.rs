//! Failure-injection tests: worker faults, poisoned backends, BUSY
//! storms, slot-leak detection — the service must degrade, not wedge.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::service::ServeError;
use windve::coordinator::{ServiceConfig, WindVE};
use windve::devices::executor::Backend;

/// Backend that panics every `nth` batch.
struct FlakyBackend {
    calls: usize,
    nth: usize,
}

impl Backend for FlakyBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.calls += 1;
        if self.calls % self.nth == 0 {
            panic!("injected fault on batch {}", self.calls);
        }
        Ok(texts.iter().map(|_| vec![1.0]).collect())
    }
    fn describe(&self) -> String {
        "flaky".into()
    }
    fn max_batch(&self) -> usize {
        4
    }
}

/// Backend that errors (not panics) on odd batches.
struct ErroringBackend {
    calls: AtomicUsize,
}

impl Backend for ErroringBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        if self.calls.fetch_add(1, Ordering::Relaxed) % 2 == 0 {
            anyhow::bail!("transient device error");
        }
        Ok(texts.iter().map(|_| vec![2.0]).collect())
    }
    fn describe(&self) -> String {
        "erroring".into()
    }
    fn max_batch(&self) -> usize {
        2
    }
}

/// Backend that returns the wrong number of vectors.
struct ShortBackend;

impl Backend for ShortBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(texts.iter().skip(1).map(|_| vec![3.0]).collect())
    }
    fn describe(&self) -> String {
        "short".into()
    }
    fn max_batch(&self) -> usize {
        8
    }
}

fn service_with(factory: BackendFactory, depth: usize) -> WindVE {
    WindVE::start(
        ServiceConfig {
            npu_depth: depth,
            cpu_depth: 0,
            hetero: false,
            npu_workers: 1,
            cpu_workers: 0,
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
            ..ServiceConfig::default()
        },
        vec![factory],
        vec![],
    )
    .unwrap()
}

#[test]
fn panicking_backend_never_wedges_service() {
    let svc = service_with(
        Box::new(|| Ok(Box::new(FlakyBackend { calls: 0, nth: 3 }) as Box<dyn Backend>)),
        64,
    );
    let mut ok = 0;
    let mut failed = 0;
    for i in 0..60 {
        match svc.embed_blocking(format!("q{i}"), Duration::from_secs(10)) {
            Ok(_) => ok += 1,
            Err(ServeError::Backend(msg)) => {
                assert!(msg.contains("injected fault"), "{msg}");
                failed += 1;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(ok > 0, "some queries must survive");
    assert!(failed > 0, "injected faults must surface as Backend errors");
    // No slots leaked despite the panics.
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
    svc.shutdown();
}

#[test]
fn erroring_backend_reports_and_recovers() {
    let svc = service_with(
        Box::new(|| {
            Ok(Box::new(ErroringBackend { calls: AtomicUsize::new(0) }) as Box<dyn Backend>)
        }),
        64,
    );
    let mut saw_error = false;
    let mut saw_ok = false;
    for i in 0..20 {
        match svc.embed_blocking(format!("q{i}"), Duration::from_secs(10)) {
            Ok(_) => saw_ok = true,
            Err(ServeError::Backend(m)) => {
                assert!(m.contains("transient device error"));
                saw_error = true;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(saw_error && saw_ok);
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

#[test]
fn wrong_arity_backend_fails_batch_safely() {
    let svc = service_with(Box::new(|| Ok(Box::new(ShortBackend) as Box<dyn Backend>)), 64);
    let err = svc
        .embed_blocking("only query", Duration::from_secs(10))
        .unwrap_err();
    match err {
        ServeError::Backend(m) => assert!(m.contains("vectors"), "{m}"),
        e => panic!("unexpected {e}"),
    }
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

#[test]
fn busy_storm_recovers_after_drain() {
    // Slow backend + tiny queue: hammer it, collect BUSYs, then verify
    // the service is fully usable afterwards.
    struct SlowBackend;
    impl Backend for SlowBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            std::thread::sleep(Duration::from_millis(30));
            Ok(texts.iter().map(|_| vec![1.0]).collect())
        }
        fn describe(&self) -> String {
            "slow".into()
        }
        fn max_batch(&self) -> usize {
            2
        }
    }
    let svc = Arc::new(service_with(
        Box::new(|| Ok(Box::new(SlowBackend) as Box<dyn Backend>)),
        2,
    ));
    let mut busy = 0;
    let mut tickets = Vec::new();
    for i in 0..50 {
        match svc.submit(format!("storm {i}")) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Busy) => busy += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert!(busy >= 40, "storm should mostly reject (busy={busy})");
    for t in tickets {
        t.wait(Duration::from_secs(10)).unwrap();
    }
    // Recovered: a fresh query goes straight through.
    assert!(svc.embed_blocking("after storm", Duration::from_secs(10)).is_ok());
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}

#[test]
fn failed_backend_init_degrades_to_errors_not_hangs() {
    let svc = service_with(Box::new(|| anyhow::bail!("artifacts missing")), 8);
    for i in 0..5 {
        let err = svc
            .embed_blocking(format!("doomed {i}"), Duration::from_secs(10))
            .unwrap_err();
        match err {
            ServeError::Backend(m) => assert!(m.contains("backend init failed"), "{m}"),
            e => panic!("unexpected {e}"),
        }
    }
    assert_eq!(svc.queue_manager().npu_occupancy(), 0);
}
