//! Property-based tests (in-repo quickcheck-lite) over the coordinator
//! invariants: routing, batching, admission state, plus the estimator and
//! substrate laws the system leans on.

use std::sync::Arc;

use windve::coordinator::batcher::{DeviceQueue, Pending};
use windve::coordinator::queue_manager::{QueueManager, Route, WorkClass};
use windve::devices::profile::DeviceProfile;
use windve::estimator::robust::theil_sen;
use windve::estimator::LinearFit;
use windve::metrics::Histogram;
use windve::sim::cluster::ClosedLoopSim;
use windve::testing::prop::{property, Gen};
use windve::util::json::{self, Json};

/// Every dispatched query gets exactly one route; occupancy never exceeds
/// depth; total admitted == depth when demand exceeds capacity.
#[test]
fn prop_queue_manager_conservation_and_bounds() {
    property("queue manager conservation", 200, |g: &mut Gen| {
        let npu_depth = g.usize(0, 64);
        let cpu_depth = g.usize(0, 32);
        let hetero = g.bool();
        let demand = g.usize(0, 160);
        let qm = QueueManager::new(npu_depth, cpu_depth, hetero);
        let mut counts = (0usize, 0usize, 0usize);
        for _ in 0..demand {
            match qm.dispatch() {
                Route::Npu => counts.0 += 1,
                Route::Cpu => counts.1 += 1,
                Route::Busy => counts.2 += 1,
            }
            if qm.npu_occupancy() > npu_depth {
                return Err(format!("npu occupancy {} > depth {npu_depth}", qm.npu_occupancy()));
            }
            if qm.cpu_occupancy() > if hetero { cpu_depth } else { 0 } {
                return Err(format!("cpu occupancy {} over depth", qm.cpu_occupancy()));
            }
        }
        if counts.0 + counts.1 + counts.2 != demand {
            return Err("conservation violated".into());
        }
        let cpu_cap = if hetero { cpu_depth } else { 0 };
        if counts.0 != demand.min(npu_depth) {
            return Err(format!("npu admitted {} != min(demand, depth)", counts.0));
        }
        if counts.1 != demand.saturating_sub(npu_depth).min(cpu_cap) {
            return Err(format!("cpu admitted {} wrong", counts.1));
        }
        Ok(())
    });
}

/// Release always restores capacity: after any interleaving of dispatch
/// and release, a drained manager admits again.
#[test]
fn prop_release_restores_capacity() {
    property("release restores capacity", 100, |g: &mut Gen| {
        let depth = g.usize(1, 16);
        let qm = QueueManager::new(depth, 0, false);
        let mut live: Vec<Route> = Vec::new();
        for _ in 0..g.usize(1, 200) {
            if g.bool() || live.is_empty() {
                match qm.dispatch() {
                    Route::Busy => {
                        if live.len() != depth {
                            return Err(format!(
                                "busy with {} in flight (depth {depth})",
                                live.len()
                            ));
                        }
                    }
                    r => live.push(r),
                }
            } else {
                let r = live.pop().unwrap();
                qm.release(r);
            }
        }
        for r in live.drain(..) {
            qm.release(r);
        }
        if qm.npu_occupancy() != 0 {
            return Err("occupancy nonzero after full release".into());
        }
        if qm.dispatch() != Route::Npu {
            return Err("drained manager must admit".into());
        }
        Ok(())
    });
}

/// Batch drains preserve FIFO order, lose nothing, and never exceed max.
#[test]
fn prop_device_queue_fifo_conservation() {
    property("device queue fifo conservation", 100, |g: &mut Gen| {
        let q: DeviceQueue<usize> = DeviceQueue::new();
        let n = g.usize(1, 200);
        for i in 0..n {
            q.push(Pending {
                text: format!("q{i}").into(),
                class: WorkClass::Embed,
                enqueued: std::time::Instant::now(),
                trace: 0,
                reply: i,
            });
        }
        let max = g.usize(1, 33);
        let mut seen = Vec::new();
        while !q.is_empty() {
            let batch = q.drain_batch(max).unwrap();
            if batch.is_empty() || batch.len() > max {
                return Err(format!("batch size {} out of bounds", batch.len()));
            }
            seen.extend(batch.into_iter().map(|p| p.reply));
        }
        if seen != (0..n).collect::<Vec<_>>() {
            return Err("FIFO order or conservation violated".into());
        }
        Ok(())
    });
}

/// OLS recovers planted lines through noise; prediction respects slope.
#[test]
fn prop_linreg_recovers_planted_line() {
    property("ols recovers planted line", 120, |g: &mut Gen| {
        let alpha = g.f64(0.001, 0.2);
        let beta = g.f64(0.0, 1.0);
        let noise = g.f64(0.0, 0.01);
        let n = g.usize(5, 40);
        let mut rng = windve::util::rng::Pcg::new(g.u64(0, 1 << 60));
        let pts: Vec<(f64, f64)> = (1..=n)
            .map(|c| {
                let t = alpha * c as f64 + beta;
                (c as f64, t + noise * rng.normal())
            })
            .collect();
        let fit = LinearFit::fit(&pts);
        let rel = (fit.alpha - alpha).abs() / alpha;
        if rel > 0.5 && (fit.alpha - alpha).abs() > 0.02 {
            return Err(format!("alpha {} vs planted {alpha}", fit.alpha));
        }
        if fit.beta < 0.0 || fit.alpha < 0.0 {
            return Err("constraint violated".into());
        }
        Ok(())
    });
}

/// Theil-Sen survives up to ~25% outliers where planted.
#[test]
fn prop_theil_sen_outlier_robust() {
    property("theil-sen outlier robust", 60, |g: &mut Gen| {
        let alpha = g.f64(0.01, 0.1);
        let beta = g.f64(0.1, 0.9);
        let mut rng = windve::util::rng::Pcg::new(g.u64(0, 1 << 60));
        // Exactly 5/24 gross outliers (~21%) — safely under Theil-Sen's
        // ~29% breakdown point (Bernoulli sampling can exceed it by luck).
        let mut outlier_at = [false; 25];
        let mut placed = 0;
        while placed < 5 {
            let i = rng.usize(1, 25);
            if !outlier_at[i] {
                outlier_at[i] = true;
                placed += 1;
            }
        }
        let pts: Vec<(f64, f64)> = (1..=24)
            .map(|c| {
                let mut t = alpha * c as f64 + beta + 0.002 * rng.normal();
                if outlier_at[c] {
                    t *= 3.0; // gross outlier
                }
                (c as f64, t)
            })
            .collect();
        let fit = theil_sen(&pts);
        let rel = (fit.alpha - alpha).abs() / alpha;
        if rel > 0.6 {
            return Err(format!("alpha {} vs planted {alpha} (rel {rel:.2})", fit.alpha));
        }
        Ok(())
    });
}

/// Histogram quantiles are monotone and bounded by min/max for any input.
#[test]
fn prop_histogram_quantiles_sane() {
    property("histogram quantile sanity", 80, |g: &mut Gen| {
        let h = Histogram::new();
        let n = g.usize(1, 500);
        let mut max = 0u64;
        for _ in 0..n {
            let v = g.u64(1, 10_000_000);
            max = max.max(v);
            h.record(v);
        }
        let mut prev = 0;
        for i in 0..=20 {
            let q = h.quantile(i as f64 / 20.0);
            if q < prev {
                return Err("quantiles not monotone".into());
            }
            prev = q;
        }
        if h.quantile(1.0) > max {
            return Err("p100 exceeds max".into());
        }
        Ok(())
    });
}

/// Histogram quantile *accuracy*: against the exact order statistic of
/// the recorded sample, the estimate is never below it and overshoots by
/// at most one bucket width (~1/32 relative — the log-bucket design
/// contract the `/v1/stats` stage quantiles rely on).
#[test]
fn prop_histogram_quantile_within_bucket_width() {
    property("histogram quantile within one bucket", 60, |g: &mut Gen| {
        let h = Histogram::new();
        let n = g.usize(32, 2000);
        // Mix magnitudes so both the identity-mapped region and the
        // log-bucketed region are exercised.
        let mut vals: Vec<u64> = (0..n)
            .map(|_| match g.usize(0, 2) {
                0 => g.u64(1, 64),
                1 => g.u64(64, 100_000),
                _ => g.u64(100_000, 10_000_000_000),
            })
            .collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for &q in &[0.5, 0.9, 0.95, 0.99] {
            let rank = ((q * n as f64).ceil() as usize).max(1) - 1;
            let exact = vals[rank];
            let est = h.quantile(q);
            if est < exact {
                return Err(format!("q={q}: est {est} below exact {exact}"));
            }
            let slack = exact / 32 + 2;
            if est - exact > slack {
                return Err(format!(
                    "q={q}: est {est} vs exact {exact} exceeds bucket width {slack}"
                ));
            }
        }
        Ok(())
    });
}

/// JSON round-trips arbitrary generated values.
#[test]
fn prop_json_roundtrip() {
    fn gen_value(g: &mut Gen, depth: usize) -> Json {
        match g.usize(0, if depth == 0 { 4 } else { 6 }) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(g.sentence(4)),
            4 => Json::Str(format!("esc\"{}\n\t", g.word())),
            5 => Json::Arr((0..g.usize(0, 4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize(0, 4))
                    .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    property("json roundtrip", 200, |g: &mut Gen| {
        let v = gen_value(g, 3);
        let s = v.to_string();
        match json::parse(&s) {
            Ok(v2) if v2 == v => Ok(()),
            Ok(v2) => Err(format!("roundtrip drift: {v} -> {v2}")),
            Err(e) => Err(format!("parse failed on {s}: {e}")),
        }
    });
}

/// Closed-loop sim: admitted batches never exceed depths, busy only when
/// demand exceeds total depth (for any profile pair and client count).
#[test]
fn prop_sim_round_respects_depths() {
    property("sim round respects depths", 150, |g: &mut Gen| {
        let profiles = [
            DeviceProfile::v100_bge(),
            DeviceProfile::xeon_e5_2690_bge(),
            DeviceProfile::atlas_300i_duo_bge(),
            DeviceProfile::kunpeng_920_bge(),
        ];
        let npu = (*g.pick(&profiles)).clone();
        let cpu = g.bool().then(|| (*g.pick(&profiles)).clone());
        let npu_depth = g.usize(0, 100);
        let cpu_depth = g.usize(0, 40);
        let clients = g.usize(0, 200);
        let mut sim =
            ClosedLoopSim::new(npu, cpu.clone(), npu_depth, cpu_depth, 75, g.u64(0, 1 << 40));
        let r = sim.round(clients);
        if r.npu_batch > npu_depth {
            return Err("npu batch over depth".into());
        }
        let cpu_cap = if cpu.is_some() { cpu_depth } else { 0 };
        if r.cpu_batch > cpu_cap {
            return Err("cpu batch over depth".into());
        }
        if r.npu_batch + r.cpu_batch + r.busy != clients {
            return Err("round conservation violated".into());
        }
        let cap = npu_depth + cpu_cap;
        if clients <= cap && r.busy > 0 {
            return Err("busy below capacity".into());
        }
        Ok(())
    });
}

/// Profile service time is monotone in batch and query length for all
/// registry devices (the assumption everything else rests on).
#[test]
fn prop_profiles_monotone() {
    property("profiles monotone", 100, |g: &mut Gen| {
        let names = ["v100", "xeon", "atlas", "kunpeng", "v100_jina", "kunpeng_jina"];
        let p = DeviceProfile::by_name(names[g.usize(0, names.len())]).unwrap();
        let b = g.usize(1, 300);
        let l = g.usize(2, 512);
        let t = p.service_time(b, l);
        if p.service_time(b + 1, l) < t {
            return Err("not monotone in batch".into());
        }
        if p.service_time(b, l + 16) < t {
            return Err("not monotone in length".into());
        }
        if t <= 0.0 {
            return Err("non-positive service time".into());
        }
        Ok(())
    });
}

/// Worker pipeline: any mix of texts through the service yields exactly
/// one reply per admitted query (conservation through threads).
#[test]
fn prop_service_reply_conservation() {
    use windve::coordinator::instance::spawn_worker;
    use windve::metrics::Registry;

    struct CountBackend;
    impl windve::devices::executor::Backend for CountBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            Ok(texts.iter().map(|t| vec![t.len() as f32]).collect())
        }
        fn describe(&self) -> String {
            "count".into()
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    property("service reply conservation", 20, |g: &mut Gen| {
        let queue = Arc::new(DeviceQueue::new());
        let qm = Arc::new(QueueManager::new(1024, 0, false));
        let worker = spawn_worker(
            "npu0".into(),
            Arc::clone(&queue),
            Arc::clone(&qm),
            Route::Npu,
            Box::new(|| Ok(Box::new(CountBackend) as Box<dyn windve::devices::executor::Backend>)),
            Registry::new(),
            None,
            None,
        );
        let n = g.usize(1, 60);
        let mut rxs = Vec::new();
        for i in 0..n {
            qm.dispatch();
            let (tx, rx) = std::sync::mpsc::channel();
            queue.push(Pending {
                text: "x".repeat(i % 17 + 1).into(),
                class: WorkClass::Embed,
                enqueued: std::time::Instant::now(),
                trace: 0,
                reply: tx,
            });
            rxs.push((i % 17 + 1, rx));
        }
        for (len, rx) in rxs {
            let v = rx
                .recv_timeout(std::time::Duration::from_secs(5))
                .map_err(|e| format!("missing reply: {e}"))?
                .map_err(|e| format!("backend err: {e}"))?;
            if v != vec![len as f32] {
                return Err(format!("reply mismatch: {v:?} vs {len}"));
            }
        }
        queue.close();
        worker.join().map_err(|_| "worker panicked".to_string())?;
        if qm.npu_occupancy() != 0 {
            return Err("slots leaked".into());
        }
        Ok(())
    });
}

/// SIMD and scalar kernels agree within 1e-4 (relative) on random panels
/// of every awkward shape: sub-lane dims, non-multiples of 8, and the
/// paper's dim-768 embeddings.
#[test]
fn prop_simd_and_scalar_kernels_agree() {
    use windve::vecstore::kernels;
    property("simd/scalar kernel agreement", 150, |g: &mut Gen| {
        let dim = *g.pick(&[1usize, 3, 5, 8, 13, 16, 31, 64, 96, 768]);
        let nq = g.usize(1, 7);
        let nrows = g.usize(1, 12);
        let queries: Vec<f32> = (0..nq * dim).map(|_| g.f64(-1.0, 1.0) as f32).collect();
        let rows: Vec<f32> = (0..nrows * dim).map(|_| g.f64(-1.0, 1.0) as f32).collect();
        let mut fast = vec![0.0f32; nq * nrows];
        let mut slow = vec![0.0f32; nq * nrows];
        kernels::panel_scores_into(&queries, nq, &rows, nrows, dim, &mut fast);
        kernels::panel_scalar(&queries, nq, &rows, nrows, dim, &mut slow);
        for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
            if (f - s).abs() > 1e-4 * (1.0 + s.abs()) {
                return Err(format!("pair {i} (dim {dim}): simd {f} vs scalar {s}"));
            }
        }
        // The dispatched single dot must agree with the panel's pairs.
        let d = kernels::dot(&queries[..dim], &rows[..dim]);
        if d.to_bits() != fast[0].to_bits() {
            return Err(format!("dot/panel divergence: {d} vs {}", fast[0]));
        }
        Ok(())
    });
}

/// `search_batch` returns exactly what per-query `search` returns (ids,
/// order, and scores) for both index types, across shard counts — the
/// acceptance bar for the batched retrieval engine.
#[test]
fn prop_search_batch_equals_per_query_search() {
    use windve::vecstore::{FlatIndex, Index, IvfIndex};
    property("search_batch == per-query search", 40, |g: &mut Gen| {
        let dim = *g.pick(&[8usize, 24, 48]);
        let n = g.usize(1, 300);
        let nq = g.usize(1, 9);
        let k = g.usize(1, 12);
        let mut flat = FlatIndex::new(dim);
        let mut ivf = IvfIndex::new(dim, 8, g.usize(1, 9));
        for i in 0..n {
            // Coarse grid values force plenty of exact score ties.
            let v: Vec<f32> = (0..dim).map(|_| (g.u32(0, 5) as f32 - 2.0) * 0.5).collect();
            flat.add(i as u64, &v);
            ivf.add(i as u64, &v);
        }
        if g.bool() {
            ivf.build(g.u64(0, 1000));
        }
        let queries: Vec<Vec<f32>> = (0..nq)
            .map(|_| (0..dim).map(|_| g.f64(-1.0, 1.0) as f32).collect())
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let shards = g.usize(1, 5);
        for (name, batch) in [
            ("flat/auto", flat.search_batch(&qrefs, k)),
            ("flat/sharded", flat.search_batch_with_threads(&qrefs, k, shards)),
            ("ivf", ivf.search_batch(&qrefs, k)),
        ] {
            let reference: &dyn Index = if name.starts_with("flat") { &flat } else { &ivf };
            for (qi, q) in queries.iter().enumerate() {
                let single = reference.search(q, k);
                if batch[qi] != single {
                    return Err(format!(
                        "{name} q{qi}: batch {:?} != single {:?}",
                        batch[qi], single
                    ));
                }
            }
        }
        Ok(())
    });
}

/// f16 round-trip: decode∘encode is the identity on every finite f16 bit
/// pattern, and encode∘decode of an arbitrary f32 errs by at most half an
/// f16 ulp (≤ |x|·2⁻¹¹ for normal magnitudes, ≤ 2⁻²⁵ in the subnormal
/// range) — the bound the quantized scan's score epsilon rests on.
#[test]
fn prop_f16_roundtrip_within_ulp() {
    use windve::vecstore::quant::{f16_to_f32, f32_to_f16};
    property("f16 roundtrip within half ulp", 300, |g: &mut Gen| {
        // Identity on representable values (random finite bit pattern).
        let h = loop {
            let h = g.u64(0, 0x10000) as u16;
            if (h >> 10) & 0x1F != 0x1F {
                break h;
            }
        };
        let back = f32_to_f16(f16_to_f32(h));
        if back != h {
            return Err(format!("finite f16 {h:#06x} drifted to {back:#06x}"));
        }
        // Error bound on arbitrary f32 inside f16's normal range.
        let x = g.f64(-60000.0, 60000.0) as f32;
        let rt = f16_to_f32(f32_to_f16(x));
        let bound = x.abs() * (1.0 / 2048.0) + 3.0e-8; // |x|·2⁻¹¹ + 2⁻²⁵
        if (rt - x).abs() > bound {
            return Err(format!("x={x}: roundtrip {rt}, err {} > {bound}", (rt - x).abs()));
        }
        Ok(())
    });
}

/// int8 codec: every dequantized element is within scale/2 of the
/// original, codes stay in [-127, 127], and the row max maps to ±127 —
/// the per-row symmetric contract the score-error bound is derived from.
#[test]
fn prop_i8_roundtrip_max_abs_error() {
    use windve::vecstore::quant::quantize_i8_row;
    property("int8 roundtrip error <= scale/2", 200, |g: &mut Gen| {
        let dim = g.usize(1, 256);
        let amp = g.f64(1e-3, 100.0);
        let v: Vec<f32> = (0..dim).map(|_| (g.f64(-1.0, 1.0) * amp) as f32).collect();
        let mut codes = vec![0i8; dim];
        let scale = quantize_i8_row(&v, &mut codes);
        let max_abs = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        if max_abs == 0.0 {
            return if scale == 0.0 && codes.iter().all(|&c| c == 0) {
                Ok(())
            } else {
                Err("zero row must encode to zero codes with zero scale".into())
            };
        }
        if (scale - max_abs / 127.0).abs() > 1e-6 * scale.abs() {
            return Err(format!("scale {scale} != max_abs/127 {}", max_abs / 127.0));
        }
        for (x, c) in v.iter().zip(&codes) {
            let err = (*c as f32 * scale - x).abs();
            if err > scale * 0.5001 + 1e-7 {
                return Err(format!("element err {err} > scale/2 {}", scale / 2.0));
            }
        }
        Ok(())
    });
}

/// Quantized flat search: every returned score is within the codec's
/// documented epsilon of the full-precision score of the same row —
/// f16 within ~1e-3 on unit vectors, int8 within ‖q‖₁·scale/2.
#[test]
fn prop_quantized_scores_within_codec_epsilon() {
    use windve::vecstore::quant::quantize_i8_row;
    use windve::vecstore::{FlatIndex, Index, Quant};
    property("quantized scores within codec epsilon", 25, |g: &mut Gen| {
        let dim = *g.pick(&[16usize, 24, 48]);
        let n = g.usize(10, 150);
        let mut flat = FlatIndex::new(dim);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for i in 0..n {
            let mut v: Vec<f32> = (0..dim).map(|_| g.rng().normal() as f32).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
            v.iter_mut().for_each(|x| *x /= norm);
            flat.add(i as u64, &v);
            rows.push(v);
        }
        let mut q: Vec<f32> = (0..dim).map(|_| g.rng().normal() as f32).collect();
        let qnorm = q.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        q.iter_mut().for_each(|x| *x /= qnorm);
        let q_l1: f32 = q.iter().map(|x| x.abs()).sum();
        for quant in Quant::modes_under_test() {
            let qidx = flat.quantize(quant);
            for hit in qidx.search(&q, 10) {
                let row = &rows[hit.id as usize];
                let exact: f32 = q.iter().zip(row).map(|(a, b)| a * b).sum();
                let eps = match quant {
                    Quant::F32 => 1e-4 * (1.0 + exact.abs()),
                    Quant::F16 => 1.5e-3 * (1.0 + exact.abs()),
                    Quant::Int8 => {
                        let mut codes = vec![0i8; dim];
                        let scale = quantize_i8_row(row, &mut codes);
                        q_l1 * scale * 0.51 + 1e-4 * (1.0 + exact.abs())
                    }
                    // n ≤ 150 is below the PQ staging threshold (256), so
                    // the arena still holds raw f32 rows and scores
                    // exactly — lossy ADC only starts once training
                    // triggers (covered by `prop_pq_scan_recall`).
                    Quant::Pq { .. } => 1e-4 * (1.0 + exact.abs()),
                };
                if (hit.score - exact).abs() > eps {
                    return Err(format!(
                        "{quant:?} id {}: score {} vs exact {exact} (eps {eps})",
                        hit.id, hit.score
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Quantized `search_batch` returns exactly what per-query `search`
/// returns (ids, order, scores) for the quantized flat and IVF indexes,
/// across shard counts — batching quantized scans must change bandwidth,
/// never results.
#[test]
fn prop_quantized_search_batch_equals_per_query() {
    use windve::vecstore::{Index, IvfIndex, Quant, QuantizedFlatIndex};
    property("quantized search_batch == per-query", 30, |g: &mut Gen| {
        let dim = *g.pick(&[8usize, 24, 48]);
        let n = g.usize(1, 250);
        let nq = g.usize(1, 8);
        let k = g.usize(1, 12);
        for quant in Quant::modes_under_test() {
            let mut qflat = QuantizedFlatIndex::new(dim, quant);
            let mut ivf = IvfIndex::with_quant(dim, 8, g.usize(1, 9), quant);
            for i in 0..n {
                // Coarse grid values force plenty of exact score ties.
                let v: Vec<f32> = (0..dim).map(|_| (g.u32(0, 5) as f32 - 2.0) * 0.5).collect();
                qflat.add(i as u64, &v);
                ivf.add(i as u64, &v);
            }
            if g.bool() {
                ivf.build(g.u64(0, 1000));
            }
            let queries: Vec<Vec<f32>> = (0..nq)
                .map(|_| (0..dim).map(|_| g.f64(-1.0, 1.0) as f32).collect())
                .collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let shards = g.usize(1, 5);
            for (name, batch) in [
                ("qflat/auto", qflat.search_batch(&qrefs, k)),
                ("qflat/sharded", qflat.search_batch_with_threads(&qrefs, k, shards)),
                ("ivf", ivf.search_batch(&qrefs, k)),
            ] {
                let reference: &dyn Index =
                    if name.starts_with("qflat") { &qflat } else { &ivf };
                for (qi, q) in queries.iter().enumerate() {
                    let single = reference.search(q, k);
                    if batch[qi] != single {
                        return Err(format!(
                            "{name} {quant:?} q{qi}: batch {:?} != single {:?}",
                            batch[qi], single
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Top-k overlap vs f32 ground truth on random Gaussian unit vectors:
/// each quantized codec must keep aggregate overlap ≥ 0.9 (and never
/// collapse on any single case) — the recall bar for scanning the
/// compact arena instead of the f32 one.
#[test]
fn prop_quantized_topk_overlap_vs_f32() {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use windve::vecstore::{FlatIndex, Index, Quant};
    let tally: RefCell<HashMap<&'static str, (u64, u64)>> = RefCell::new(HashMap::new());
    let k = 10usize;
    property("quantized top-k overlap >= 0.9", 25, |g: &mut Gen| {
        let dim = 16;
        let n = 200;
        let mut flat = FlatIndex::new(dim);
        for i in 0..n {
            let mut v: Vec<f32> = (0..dim).map(|_| g.rng().normal() as f32).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
            v.iter_mut().for_each(|x| *x /= norm);
            flat.add(i as u64, &v);
        }
        let queries: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v: Vec<f32> = (0..dim).map(|_| g.rng().normal() as f32).collect();
                let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
                v.iter_mut().for_each(|x| *x /= norm);
                v
            })
            .collect();
        for quant in Quant::modes_under_test() {
            let qidx = flat.quantize(quant);
            let mut case_hits = 0u64;
            for q in &queries {
                let truth: Vec<u64> = flat.search(q, k).into_iter().map(|h| h.id).collect();
                let approx = qidx.search(q, k);
                case_hits += approx.iter().filter(|h| truth.contains(&h.id)).count() as u64;
            }
            let mut t = tally.borrow_mut();
            let e = t.entry(quant.name()).or_insert((0, 0));
            e.0 += case_hits;
            e.1 += (queries.len() * k) as u64;
            // A single catastrophic case means the codec is broken, not
            // just noisy at the k-boundary.
            let case_overlap = case_hits as f64 / (queries.len() * k) as f64;
            if case_overlap < 0.6 {
                return Err(format!("{quant:?} case overlap {case_overlap:.2} < 0.6"));
            }
        }
        Ok(())
    });
    for (codec, (hits, total)) in tally.borrow().iter() {
        let overlap = *hits as f64 / *total as f64;
        assert!(overlap >= 0.9, "{codec}: aggregate top-{k} overlap {overlap:.3} < 0.9");
    }
}

/// Trained PQ (the lossy regime, past the staging threshold) on clustered
/// corpora: top-10 recall vs the f32 exact scan stays ≥ 0.9 in aggregate
/// for {flat, IVF full-probe} × {pq4, pq8}; `search_batch` is
/// bit-identical to per-query `search`; and PQ snapshots round-trip
/// bit-identically through tombstone + decode, with compaction changing
/// no results. Rows interleave across clusters so the training prefix
/// sees every mode of the distribution.
#[test]
fn prop_pq_scan_recall() {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use windve::vecstore::persist::decode_index;
    use windve::vecstore::{FlatIndex, Index, IvfIndex, Quant, QuantizedFlatIndex};
    let tally: RefCell<HashMap<String, (u64, u64)>> = RefCell::new(HashMap::new());
    let k = 10usize;
    property("pq trained-scan recall >= 0.9", 12, |g: &mut Gen| {
        let dim = *g.pick(&[16usize, 32]);
        let ncl = g.usize(4, 8);
        let n = g.usize(280, 380);
        // Unit cluster centers, then rows = center + small noise,
        // assigned round-robin so the first 256 rows (the PQ training
        // prefix for the flat arena) cover every cluster.
        let centers: Vec<Vec<f32>> = (0..ncl)
            .map(|_| {
                let mut v: Vec<f32> = (0..dim).map(|_| g.rng().normal() as f32).collect();
                let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
                v.iter_mut().for_each(|x| *x /= norm);
                v
            })
            .collect();
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let c = &centers[i % ncl];
                let mut v: Vec<f32> =
                    c.iter().map(|x| x + 0.1 * g.rng().normal() as f32).collect();
                let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
                v.iter_mut().for_each(|x| *x /= norm);
                v
            })
            .collect();
        let mut flat = FlatIndex::new(dim);
        for (i, v) in rows.iter().enumerate() {
            flat.add(i as u64, v);
        }
        // Queries: perturbed cluster centers (what RAG traffic looks
        // like when the corpus is clustered).
        let queries: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let c = g.pick(&centers).clone();
                let mut v: Vec<f32> =
                    c.iter().map(|x| x + 0.1 * g.rng().normal() as f32).collect();
                let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
                v.iter_mut().for_each(|x| *x /= norm);
                v
            })
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        for quant in [Quant::pq(4), Quant::pq(8)] {
            let mut qflat = QuantizedFlatIndex::new(dim, quant);
            // Full probe: IVF recall differences come from the codec
            // alone, not from probing.
            let mut ivf = IvfIndex::with_quant(dim, 6, 6, quant);
            for (i, v) in rows.iter().enumerate() {
                qflat.add(i as u64, v);
                ivf.add(i as u64, v);
            }
            ivf.build(g.u64(0, 1000));
            if !qflat.pq_trained() {
                return Err(format!("{quant:?}: {n} rows must train the flat arena"));
            }
            for (name, idx) in
                [("flat", &qflat as &dyn Index), ("ivf", &ivf as &dyn Index)]
            {
                // Recall vs the f32 exact scan.
                let mut case_hits = 0u64;
                for q in &queries {
                    let truth: Vec<u64> =
                        flat.search(q, k).into_iter().map(|h| h.id).collect();
                    let approx = idx.search(q, k);
                    case_hits +=
                        approx.iter().filter(|h| truth.contains(&h.id)).count() as u64;
                }
                let denom = (queries.len() * k) as u64;
                let case_recall = case_hits as f64 / denom as f64;
                if case_recall < 0.5 {
                    return Err(format!(
                        "{name}/{}: case recall {case_recall:.2} < 0.5",
                        quant.name()
                    ));
                }
                let mut t = tally.borrow_mut();
                let e = t.entry(format!("{name}/{}", quant.name())).or_insert((0, 0));
                e.0 += case_hits;
                e.1 += denom;
                // Batch must be bit-identical to per-query search.
                let batch = idx.search_batch(&qrefs, k);
                for (qi, q) in queries.iter().enumerate() {
                    if batch[qi] != idx.search(q, k) {
                        return Err(format!(
                            "{name}/{}: batch != single for q{qi}",
                            quant.name()
                        ));
                    }
                }
            }
            // Tombstone + persist round-trip: the restored index scores
            // bit-identically to the source with its skip mask engaged.
            qflat.remove(3);
            qflat.remove((n / 2) as u64);
            let restored = decode_index(&qflat.snapshot_bytes().unwrap())
                .map_err(|e| format!("{quant:?}: decode failed: {e}"))?;
            for q in &queries {
                let a: Vec<(u64, u32)> =
                    restored.search(q, k).iter().map(|h| (h.id, h.score.to_bits())).collect();
                let b: Vec<(u64, u32)> =
                    qflat.search(q, k).iter().map(|h| (h.id, h.score.to_bits())).collect();
                if a != b {
                    return Err(format!("{quant:?}: persisted scan diverged"));
                }
            }
            // Compaction drops the tombstones without changing results.
            let before: Vec<_> = queries.iter().map(|q| qflat.search(q, k)).collect();
            qflat.compact();
            if qflat.tombstones() != 0 {
                return Err("compact left tombstones".into());
            }
            for (q, want) in queries.iter().zip(&before) {
                if &qflat.search(q, k) != want {
                    return Err(format!("{quant:?}: compaction changed results"));
                }
            }
        }
        Ok(())
    });
    for (combo, (hits, total)) in tally.borrow().iter() {
        let recall = *hits as f64 / *total as f64;
        assert!(recall >= 0.9, "{combo}: aggregate top-{k} recall {recall:.3} < 0.9");
    }
}

/// Weighted multi-class admission invariants (extended to the NPU
/// retrieval leg and the ingest class): under arbitrary interleavings
/// of `dispatch_class` / `dispatch_retrieve_npu` / `dispatch_ingest_npu`
/// / `release_class`, occupancy never exceeds any depth (either pool,
/// any per-class cap), the per-class occupancies always sum to their
/// pool occupancy on BOTH device legs, every admit has a matching
/// release that drains the manager to zero, and `bad_releases` stays 0
/// for well-formed sequences.
#[test]
fn prop_class_admission_invariants() {
    use windve::coordinator::queue_manager::ClassCaps;
    property("class admission invariants", 150, |g: &mut Gen| {
        let npu_depth = g.usize(0, 24);
        let cpu_pool = g.usize(0, 33);
        let cap = g.usize(0, cpu_pool + 1);
        let npu_cap = g.usize(0, npu_depth + 1);
        let ingest_cap = g.usize(0, cpu_pool + 1);
        let npu_ingest_cap = g.usize(0, npu_depth + 1);
        let hetero = g.bool();
        let qm = QueueManager::with_caps(
            npu_depth,
            cpu_pool,
            hetero,
            ClassCaps {
                retrieve: cap,
                npu_retrieve: npu_cap,
                ingest: ingest_cap,
                npu_ingest: npu_ingest_cap,
            },
        );
        let mut live: Vec<(WorkClass, Route, usize)> = Vec::new();
        let mut admits = 0u64;
        for _ in 0..g.usize(1, 250) {
            if g.bool() || live.is_empty() {
                let class = match g.usize(0, 4) {
                    0 => WorkClass::Retrieve,
                    1 => WorkClass::Ingest,
                    _ => WorkClass::Embed,
                };
                let cost = match class {
                    WorkClass::Embed => g.usize(1, 4),
                    WorkClass::Retrieve => g.usize(1, 8),
                    WorkClass::Ingest => g.usize(1, 3),
                };
                // Retrieval and ingest pick a device leg at random;
                // embeds follow Algorithm 1 as always.
                let route = match class {
                    WorkClass::Retrieve if g.bool() => qm.dispatch_retrieve_npu(cost),
                    WorkClass::Ingest if g.bool() => qm.dispatch_ingest_npu(cost),
                    _ => qm.dispatch_class(class, cost),
                };
                match route {
                    Route::Busy => {}
                    r => {
                        admits += 1;
                        live.push((class, r, cost));
                    }
                }
            } else {
                let i = g.usize(0, live.len());
                let (c, r, cost) = live.swap_remove(i);
                qm.release_class(c, r, cost);
            }
            if qm.npu_occupancy() > npu_depth {
                return Err(format!("npu occupancy {} > depth {npu_depth}", qm.npu_occupancy()));
            }
            if qm.cpu_occupancy() > cpu_pool {
                return Err(format!("cpu occupancy {} > pool {cpu_pool}", qm.cpu_occupancy()));
            }
            if qm.retrieve_cpu_occupancy() > cap {
                return Err(format!(
                    "retrieval occupancy {} > cap {cap}",
                    qm.retrieve_cpu_occupancy()
                ));
            }
            if qm.retrieve_npu_occupancy() > npu_cap {
                return Err(format!(
                    "npu retrieval occupancy {} > cap {npu_cap}",
                    qm.retrieve_npu_occupancy()
                ));
            }
            if qm.ingest_cpu_occupancy() > ingest_cap {
                return Err(format!(
                    "ingest occupancy {} > cap {ingest_cap}",
                    qm.ingest_cpu_occupancy()
                ));
            }
            if qm.ingest_npu_occupancy() > npu_ingest_cap {
                return Err(format!(
                    "npu ingest occupancy {} > cap {npu_ingest_cap}",
                    qm.ingest_npu_occupancy()
                ));
            }
            let class_sum = qm.embed_cpu_occupancy()
                + qm.retrieve_cpu_occupancy()
                + qm.ingest_cpu_occupancy();
            if class_sum != qm.cpu_occupancy() {
                return Err(format!(
                    "per-class sum {class_sum} != pool occupancy {}",
                    qm.cpu_occupancy()
                ));
            }
            let npu_sum = qm.embed_npu_occupancy()
                + qm.retrieve_npu_occupancy()
                + qm.ingest_npu_occupancy();
            if npu_sum != qm.npu_occupancy() {
                return Err(format!(
                    "npu per-class sum {npu_sum} != pool occupancy {}",
                    qm.npu_occupancy()
                ));
            }
        }
        for (c, r, cost) in live.drain(..) {
            qm.release_class(c, r, cost);
        }
        if qm.npu_occupancy() != 0
            || qm.cpu_occupancy() != 0
            || qm.embed_cpu_occupancy() != 0
            || qm.retrieve_cpu_occupancy() != 0
            || qm.ingest_cpu_occupancy() != 0
            || qm.embed_npu_occupancy() != 0
            || qm.retrieve_npu_occupancy() != 0
            || qm.ingest_npu_occupancy() != 0
        {
            return Err("occupancy nonzero after releasing every admit".into());
        }
        let st = qm.stats();
        if st.bad_releases != 0 {
            return Err(format!("{} bad_releases on a well-formed sequence", st.bad_releases));
        }
        if st.routed_npu
            + st.routed_cpu
            + st.routed_retrieve
            + st.routed_retrieve_npu
            + st.routed_ingest
            + st.routed_ingest_npu
            != admits
        {
            return Err("admit counters disagree with observed admissions".into());
        }
        Ok(())
    });
}

/// Double-released retrieval slots are contained: counted, saturating,
/// and incapable of freeing capacity the embed class legitimately holds.
#[test]
fn prop_retrieval_double_release_contained() {
    property("retrieval double release containment", 100, |g: &mut Gen| {
        let cpu_pool = g.usize(1, 17);
        let cap = g.usize(1, cpu_pool + 1);
        let npu_depth = g.usize(0, 8);
        let qm = QueueManager::with_retrieval_cap(npu_depth, cpu_pool, true, cap);
        // Embeds legitimately holding NPU slots and CPU pool units.
        for _ in 0..g.usize(0, 24) {
            let _ = qm.dispatch();
        }
        // One well-formed scan: admitted (maybe) and released exactly once.
        let cost = g.usize(1, 5);
        if qm.dispatch_class(WorkClass::Retrieve, cost) == Route::Cpu {
            qm.release_class(WorkClass::Retrieve, Route::Cpu, cost);
        }
        if qm.retrieve_cpu_occupancy() != 0 {
            return Err("matched release left retrieval occupancy".into());
        }
        let held_cpu = qm.cpu_occupancy();
        let held_npu = qm.npu_occupancy();
        // Rogue double releases: each is counted; none frees embed slots.
        let extra = g.usize(1, 8);
        for _ in 0..extra {
            qm.release_class(WorkClass::Retrieve, Route::Cpu, cost);
        }
        if qm.cpu_occupancy() != held_cpu {
            return Err("rogue retrieval release freed embed pool units".into());
        }
        if qm.npu_occupancy() != held_npu {
            return Err("rogue retrieval release touched the NPU pool".into());
        }
        if qm.stats().bad_releases != extra as u64 {
            return Err(format!("bad_releases {} != {extra}", qm.stats().bad_releases));
        }
        // Admission capacity intact: retrieval fills exactly the cap or
        // the pool remainder, whichever binds.
        let mut got = 0;
        while qm.dispatch_class(WorkClass::Retrieve, 1) == Route::Cpu {
            got += 1;
            if got > cpu_pool {
                return Err("retrieval admitted past the pool".into());
            }
        }
        let want = cap.min(cpu_pool - qm.embed_cpu_occupancy());
        if got != want {
            return Err(format!("post-abuse capacity {got} != expected {want}"));
        }
        Ok(())
    });
}

/// Double-released NPU-leg scan slots are contained exactly like the
/// CPU leg's: counted, saturating, and incapable of freeing capacity
/// embed queries hold on the shared NPU pool — cross-class containment
/// across device legs.
#[test]
fn prop_npu_leg_double_release_contained() {
    property("npu leg double release containment", 100, |g: &mut Gen| {
        let npu_depth = g.usize(1, 17);
        let npu_cap = g.usize(1, npu_depth + 1);
        let qm = QueueManager::with_class_caps(npu_depth, 0, false, 0, npu_cap);
        // Embeds legitimately holding NPU pool slots.
        for _ in 0..g.usize(0, 24) {
            let _ = qm.dispatch();
        }
        // One well-formed offloaded scan: admitted (maybe), released once.
        let cost = g.usize(1, 5);
        if qm.dispatch_retrieve_npu(cost) == Route::Npu {
            qm.release_class(WorkClass::Retrieve, Route::Npu, cost);
        }
        if qm.retrieve_npu_occupancy() != 0 {
            return Err("matched release left npu retrieval occupancy".into());
        }
        let held = qm.npu_occupancy();
        // Rogue double releases: counted; none frees embed-held slots.
        let extra = g.usize(1, 8);
        for _ in 0..extra {
            qm.release_class(WorkClass::Retrieve, Route::Npu, cost);
        }
        if qm.npu_occupancy() != held {
            return Err("rogue npu-leg release freed embed pool units".into());
        }
        if qm.stats().bad_releases != extra as u64 {
            return Err(format!("bad_releases {} != {extra}", qm.stats().bad_releases));
        }
        // Admission capacity intact: the leg fills exactly its cap or
        // the pool remainder, whichever binds.
        let mut got = 0;
        while qm.dispatch_retrieve_npu(1) == Route::Npu {
            got += 1;
            if got > npu_depth {
                return Err("npu leg admitted past the pool".into());
            }
        }
        let want = npu_cap.min(npu_depth - qm.embed_npu_occupancy());
        if got != want {
            return Err(format!("post-abuse capacity {got} != expected {want}"));
        }
        Ok(())
    });
}

/// Mismatched queue releases saturate at zero occupancy, are counted,
/// and never corrupt subsequent admission accounting.
#[test]
fn prop_queue_release_underflow_is_contained() {
    property("release underflow containment", 100, |g: &mut Gen| {
        let npu_depth = g.usize(1, 16);
        let cpu_depth = g.usize(0, 8);
        let qm = QueueManager::new(npu_depth, cpu_depth, true);
        let extra_releases = g.usize(1, 10);
        for _ in 0..extra_releases {
            qm.release(if g.bool() { Route::Npu } else { Route::Cpu });
        }
        if qm.npu_occupancy() != 0 || qm.cpu_occupancy() != 0 {
            return Err("occupancy went negative/wrapped".into());
        }
        if qm.stats().bad_releases != extra_releases as u64 {
            return Err(format!(
                "bad_releases {} != {extra_releases}",
                qm.stats().bad_releases
            ));
        }
        // Admission capacity is intact: we can still fill to exactly depth.
        let mut npu = 0;
        loop {
            match qm.dispatch() {
                Route::Npu => npu += 1,
                _ => break,
            }
        }
        if npu != npu_depth {
            return Err(format!("admitted {npu} != depth {npu_depth}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Streaming-ingest parser: the zero-copy/incremental parser must agree
// with util::json::parse on every valid document, under every chunking.
// ---------------------------------------------------------------------------

/// Random JSON document generator (bounded depth/size), biased toward
/// the hazards the ingest lexer must survive: escapes, multi-byte UTF-8,
/// exotic-but-valid numbers.
fn gen_json(g: &mut Gen, depth: usize) -> Json {
    let pick = if depth >= 3 { g.usize(0, 4) } else { g.usize(0, 6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => gen_number(g),
        3 => Json::Str(gen_text(g)),
        4 => Json::Arr((0..g.usize(0, 5)).map(|_| gen_json(g, depth + 1)).collect()),
        _ => Json::Obj(
            (0..g.usize(0, 5))
                .map(|_| (gen_text(g), gen_json(g, depth + 1)))
                .collect(),
        ),
    }
}

fn gen_number(g: &mut Gen) -> Json {
    match g.usize(0, 5) {
        0 => Json::Num(g.u64(0, 1_000_000) as f64),
        1 => Json::Num(-(g.u64(0, 1_000_000) as f64)),
        2 => Json::Num(g.f64(-1e6, 1e6)),
        // Exponent-edge magnitudes (serialize to long digit runs).
        3 => Json::Num(g.f64(1.0, 9.0) * 10f64.powi(g.usize(0, 60) as i32)),
        _ => Json::Num(g.f64(1.0, 9.0) * 10f64.powi(-(g.usize(0, 60) as i32))),
    }
}

fn gen_text(g: &mut Gen) -> String {
    const PALETTE: &[&str] = &[
        "a", "Z", "0", " ", "\"", "\\", "\n", "\t", "\r", "\u{1}", "\u{1f}", "é", "ß",
        "日", "本", "😀", "𝕊", "/", "{", "}", "[", ",",
    ];
    let n = g.usize(0, 12);
    (0..n).map(|_| *g.pick(PALETTE)).collect()
}

/// The satellite's core equivalence: for arbitrary valid JSON (values,
/// escapes, numbers incl. exponent edge cases), the ingest parser and
/// util::json::parse produce the same document — zero-copy over slices
/// AND incrementally over arbitrary chunkings of the same bytes.
#[test]
fn prop_ingest_parser_agrees_with_util_json() {
    use windve::ingest::{parse_slice, parse_value, ChunkLexer};

    property("ingest parser == util::json on valid docs", 300, |g: &mut Gen| {
        let doc = gen_json(g, 0);
        let text = doc.to_string();
        let reference = json::parse(&text).map_err(|e| format!("util parse failed: {e}"))?;

        // Zero-copy slice parse.
        let sliced = parse_slice(text.as_bytes())
            .map_err(|e| format!("slice parse failed on {text:?}: {e}"))?;
        if sliced.to_json() != reference {
            return Err(format!("slice parse diverged on {text:?}"));
        }

        // Incremental parse over a random chunking (1-byte chunks
        // included — every escape/UTF-8 seam position gets hit across
        // the run).
        let bytes = text.as_bytes();
        let step = g.usize(1, 9);
        let chunks: Vec<std::io::Result<Vec<u8>>> =
            bytes.chunks(step).map(|c| Ok(c.to_vec())).collect();
        let mut lx = ChunkLexer::new(chunks.into_iter());
        let chunked = parse_value(&mut lx)
            .map_err(|e| format!("chunked parse failed on {text:?} (step {step}): {e}"))?;
        if chunked.to_json() != reference {
            return Err(format!("chunked parse diverged on {text:?} (step {step})"));
        }
        Ok(())
    });
}

/// Number-literal edge cases straight from text (exponents, signs,
/// leading zeros in exponents) — both parsers, same f64.
#[test]
fn prop_ingest_number_literals_match_util_json() {
    use windve::ingest::parse_slice;

    let literals = [
        "0", "-0", "1e-7", "1E-7", "1e+7", "5E+3", "2.5e300", "-2.5e-300", "1e-308",
        "9007199254740993", "0.1", "-0.25", "3e0", "7.0e01", "123456789.000001",
    ];
    for lit in literals {
        let ours = parse_slice(lit.as_bytes()).unwrap().to_json();
        let theirs = json::parse(lit).unwrap();
        match (&ours, &theirs) {
            (Json::Num(a), Json::Num(b)) => {
                assert_eq!(a.to_bits(), b.to_bits(), "{lit}: {a} vs {b}")
            }
            other => panic!("{lit}: non-number parse {other:?}"),
        }
    }
}

/// Malformed-chunk fuzz: truncations and byte corruptions of valid
/// documents, re-chunked at arbitrary seams (split escapes, split UTF-8
/// sequences) must never panic, and the chunked parser must reach
/// exactly the same verdict as the slice parser.
#[test]
fn prop_ingest_chunked_fuzz_matches_slice_on_malformed_input() {
    use windve::ingest::{parse_value, ChunkLexer, SliceLexer};

    property("chunked == slice on mangled docs", 300, |g: &mut Gen| {
        let doc = gen_json(g, 0);
        let mut bytes = doc.to_string().into_bytes();
        // Mangle: truncate, corrupt a byte, or leave intact.
        match g.usize(0, 3) {
            0 if !bytes.is_empty() => {
                bytes.truncate(g.usize(0, bytes.len()));
            }
            1 if !bytes.is_empty() => {
                let i = g.usize(0, bytes.len());
                bytes[i] = g.u32(0, 256) as u8;
            }
            _ => {}
        }

        let slice_result = {
            let mut lx = SliceLexer::new(&bytes);
            parse_value(&mut lx).map(|v| v.to_json())
        };
        let step = g.usize(1, 7);
        let chunks: Vec<std::io::Result<Vec<u8>>> =
            bytes.chunks(step).map(|c| Ok(c.to_vec())).collect();
        let mut lx = ChunkLexer::new(chunks.into_iter());
        let chunk_result = parse_value(&mut lx).map(|v| v.to_json());

        match (slice_result, chunk_result) {
            (Ok(a), Ok(b)) if a == b => Ok(()),
            (Err(_), Err(_)) => Ok(()),
            (a, b) => Err(format!(
                "verdicts diverged on {:?} (step {step}): slice {a:?} vs chunked {b:?}",
                String::from_utf8_lossy(&bytes)
            )),
        }
    });
}

/// NDJSON document streams parse identically however the network
/// fragments them, and malformed tails stop cleanly.
#[test]
fn prop_ingest_ndjson_stream_chunking_invariant() {
    use windve::ingest::{docs_from_chunks, DocStream, SliceLexer};

    property("ndjson stream chunking invariant", 100, |g: &mut Gen| {
        let n = g.usize(1, 12);
        let mut body = String::new();
        for i in 0..n {
            let doc = Json::obj(vec![
                ("id", Json::Num(i as f64)),
                ("text", Json::Str(gen_text(g))),
            ]);
            body.push_str(&doc.to_string());
            body.push('\n');
        }
        let want: Vec<_> = DocStream::new(SliceLexer::new(body.as_bytes()))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| format!("slice stream failed: {e}"))?;
        if want.len() != n {
            return Err(format!("expected {n} docs, got {}", want.len()));
        }
        let step = g.usize(1, 9);
        let chunks: Vec<std::io::Result<Vec<u8>>> =
            body.as_bytes().chunks(step).map(|c| Ok(c.to_vec())).collect();
        let got: Vec<_> = docs_from_chunks(chunks.into_iter())
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| format!("chunked stream failed: {e}"))?;
        if got != want {
            return Err(format!("doc streams diverged at step {step}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Durability: crash-replay prefix property.
// ---------------------------------------------------------------------------

/// Any mutation history × any injected crash point: recovery yields exactly
/// a prefix of the submitted history that covers every acked operation —
/// no loss, no duplicates, no reordering, and replayed rows are
/// bit-identical to what the live index held. The recovered prefix may
/// run one past the acked count (a record can be WAL-durable — or survive
/// as a torn-tail record the crash kept whole — without its ack having
/// been delivered); the contract allows that prefix *extension* and
/// nothing else.
#[test]
fn prop_durability_replay_is_acked_prefix() {
    use std::collections::HashMap;
    use std::path::Path;

    use windve::devices::executor::RetrievalExecutor;
    use windve::durability::{DurabilityOptions, DurableStore, FaultFs, FaultPlan, Fs};
    use windve::testing::pseudo_embedding;
    use windve::vecstore::FlatIndex;

    const DIM: usize = 8;

    enum Op {
        Upsert(u64, String),
        Delete(u64),
    }

    /// Log + commit one op; false means the store refused the ack.
    fn apply(store: &DurableStore, exec: &RetrievalExecutor, op: &Op) -> bool {
        match op {
            Op::Upsert(id, text) => {
                let v = pseudo_embedding(text, DIM);
                store
                    .log_upserts(&[(*id, text.as_str())], || {
                        exec.upsert_batch(&[(*id, v)]);
                    })
                    .is_ok()
            }
            Op::Delete(id) => store
                .log_delete(*id, || {
                    exec.remove(*id);
                })
                .is_ok(),
        }
    }

    property("durability crash-replay acked prefix", 20, |g: &mut Gen| {
        // A short mutation history over a small id space (small so deletes
        // hit live docs and upserts overwrite).
        let n_ops = g.usize(1, 12);
        let mut ops: Vec<Op> = Vec::new();
        for i in 0..n_ops {
            let id = g.u64(0, 6);
            if g.chance(0.3) {
                ops.push(Op::Delete(id));
            } else {
                ops.push(Op::Upsert(id, format!("doc {id} rev {i}")));
            }
        }
        let opts = DurabilityOptions {
            segment_bytes: *g.pick(&[64usize, 1 << 20]),
            compact_tombstone_ratio: 0.0,
        };
        let recover = |fs: &Arc<FaultFs>| {
            let dynfs: Arc<dyn Fs> = fs.clone();
            DurableStore::recover(
                dynfs,
                Path::new("/prop"),
                opts.clone(),
                || Box::new(FlatIndex::new(DIM)),
                |text| Ok(pseudo_embedding(text, DIM)),
            )
            .map_err(|e| e.to_string())
        };

        // states[j] = the corpus after the first j operations.
        let mut states: Vec<HashMap<u64, String>> = vec![HashMap::new()];
        for op in &ops {
            let mut next = states.last().unwrap().clone();
            match op {
                Op::Upsert(id, text) => {
                    next.insert(*id, text.clone());
                }
                Op::Delete(id) => {
                    next.remove(id);
                }
            }
            states.push(next);
        }

        // Fault-free run sizes the kill-point space (recovery of an empty
        // store performs no mutating fs ops, so every op number below
        // lands inside the mutation history).
        let fs = Arc::new(FaultFs::new());
        let (store, exec, _) = recover(&fs)?;
        for op in &ops {
            if !apply(&store, &exec, op) {
                return Err("fault-free apply refused an ack".into());
            }
        }
        let total = fs.ops();

        for kill in 0..total {
            // torn_keep 64 covers a whole record: the in-flight append can
            // survive the crash intact, exercising the j == acked + 1 arm.
            let torn = *g.pick(&[0usize, 1, 3, 7, 64]);
            let fs = Arc::new(FaultFs::with_plan(FaultPlan {
                crash_at_op: Some(kill),
                torn_keep: torn,
                ..Default::default()
            }));
            let (store, exec, _) = recover(&fs)?;
            let mut acked = 0usize;
            for op in &ops {
                if !apply(&store, &exec, op) {
                    break;
                }
                acked += 1;
            }
            if acked == ops.len() {
                return Err(format!("kill at op {kill}/{total} never fired"));
            }
            fs.restart(FaultPlan::default());
            let (store2, exec2, report) =
                recover(&fs).map_err(|e| format!("recovery after kill {kill}: {e}"))?;

            let j = store2.stats().committed_seq as usize;
            if j < acked || j > acked + 1 {
                return Err(format!(
                    "kill {kill} torn {torn}: recovered prefix {j} outside [{acked}, {}]",
                    acked + 1
                ));
            }
            if report.replayed != j as u64 {
                return Err(format!(
                    "kill {kill}: replayed {} records but committed_seq is {j}",
                    report.replayed
                ));
            }
            let want = &states[j];
            let (ids, rows, _version) = exec2
                .export_corpus()
                .ok_or_else(|| format!("kill {kill}: flat index must export its corpus"))?;
            if ids.len() != want.len() {
                return Err(format!(
                    "kill {kill} torn {torn}: {} live docs, want {} (j={j}, acked={acked})",
                    ids.len(),
                    want.len()
                ));
            }
            let mut got: HashMap<u64, &[f32]> = HashMap::new();
            for (row, id) in ids.iter().enumerate() {
                if got.insert(*id, &rows[row * DIM..(row + 1) * DIM]).is_some() {
                    return Err(format!("kill {kill}: duplicate id {id} after replay"));
                }
            }
            for (id, text) in want {
                let w = pseudo_embedding(text, DIM);
                let r = got
                    .get(id)
                    .ok_or_else(|| format!("kill {kill}: acked doc {id} lost (j={j})"))?;
                if r.iter().zip(&w).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("kill {kill}: doc {id} replayed with different bits"));
                }
            }
        }
        Ok(())
    });
}

/// NUMA-banded pinned scans are bit-identical to the plain sharded scan
/// — the tentpole acceptance bar: across `FlatIndex` and
/// `QuantizedFlatIndex` × {f32, f16, int8}, with tombstones and under
/// compaction, a synthetic multi-node plan (band shards + pinned
/// threads + first-touch realigned arenas) must change placement only,
/// never a single id or score bit.
#[test]
fn prop_numa_banded_scan_is_bit_identical() {
    use windve::devices::affinity::Topology;
    use windve::vecstore::{FlatIndex, Hit, Index, IvfIndex, Quant, QuantizedFlatIndex};

    fn bit_eq(name: &str, a: &[Vec<Hit>], b: &[Vec<Hit>]) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("{name}: {} vs {} result lists", a.len(), b.len()));
        }
        for (qi, (x, y)) in a.iter().zip(b).enumerate() {
            if x.len() != y.len() {
                return Err(format!("{name} q{qi}: {} vs {} hits", x.len(), y.len()));
            }
            for (h1, h2) in x.iter().zip(y) {
                if h1.id != h2.id || h1.score.to_bits() != h2.score.to_bits() {
                    return Err(format!("{name} q{qi}: {h1:?} != {h2:?}"));
                }
            }
        }
        Ok(())
    }

    property("numa banded scan == unpinned scan", 25, |g: &mut Gen| {
        let dim = *g.pick(&[8usize, 24, 48]);
        let n = g.usize(1, 300);
        let nq = g.usize(1, 6);
        let k = g.usize(1, 12);
        let threads = g.usize(1, 6);
        // Synthetic multi-node topology: the plan realigns arenas and
        // band-shards the scan; the pinning syscall itself is
        // best-effort (CI hosts are usually single-node), so the
        // determinism must come from the band partition + global seqs.
        let nodes = *g.pick(&[2usize, 3, 4]);
        let topo = Topology::new(nodes * 2, nodes);
        // Coarse grid rows force plenty of exact score ties.
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| (g.u32(0, 5) as f32 - 2.0) * 0.5).collect())
            .collect();
        let kill: Vec<u64> = (0..g.usize(0, 3)).map(|_| g.u64(0, n as u64 - 1)).collect();
        let queries: Vec<Vec<f32>> = (0..nq)
            .map(|_| (0..dim).map(|_| g.f64(-1.0, 1.0) as f32).collect())
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

        {
            let mut plain = FlatIndex::new(dim);
            let mut banded = FlatIndex::new(dim);
            for (i, v) in rows.iter().enumerate() {
                plain.add(i as u64, v);
                banded.add(i as u64, v);
            }
            for id in &kill {
                plain.remove(*id);
                banded.remove(*id);
            }
            if !banded.set_numa(Some(topo.clone())) {
                return Err("FlatIndex must support set_numa".into());
            }
            let want = plain.search_batch_with_threads(&qrefs, k, threads);
            bit_eq("flat", &want, &banded.search_batch_with_threads(&qrefs, k, threads))?;
            // Compaction under an active plan re-places the arena and
            // must stay bit-identical too.
            banded.compact();
            bit_eq("flat/compacted", &want, &banded.search_batch_with_threads(&qrefs, k, threads))?;
            // Reverting the plan restores the plain path, same bits.
            banded.set_numa(None);
            bit_eq("flat/reverted", &want, &banded.search_batch_with_threads(&qrefs, k, threads))?;
        }

        for quant in Quant::modes_under_test() {
            let mut plain = QuantizedFlatIndex::new(dim, quant);
            let mut banded = QuantizedFlatIndex::new(dim, quant);
            for (i, v) in rows.iter().enumerate() {
                plain.add(i as u64, v);
                banded.add(i as u64, v);
            }
            for id in &kill {
                plain.remove(*id);
                banded.remove(*id);
            }
            if !banded.set_numa(Some(topo.clone())) {
                return Err(format!("QuantizedFlatIndex({quant:?}) must support set_numa"));
            }
            let want = plain.search_batch_with_threads(&qrefs, k, threads);
            let name = format!("qflat/{quant:?}");
            bit_eq(&name, &want, &banded.search_batch_with_threads(&qrefs, k, threads))?;
            banded.compact();
            bit_eq(&name, &want, &banded.search_batch_with_threads(&qrefs, k, threads))?;
        }

        // Indexes without NUMA support refuse the plan (the service
        // falls back to plain sharding instead of mis-sharding probes).
        let mut ivf = IvfIndex::new(dim, 4, 2);
        if ivf.set_numa(Some(topo)) {
            return Err("IvfIndex must report no NUMA support".into());
        }
        Ok(())
    });
}
