//! HTTP front-end integration: a live server over a synthetic-backend
//! service, exercised with a raw TCP client (no HTTP client crate).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::{ServiceConfig, WindVE};
use windve::devices::executor::{Backend, SyntheticBackend};
use windve::devices::profile::DeviceProfile;
use windve::server::Server;
use windve::util::json;

fn synth_factory(seed: u64) -> BackendFactory {
    Box::new(move || {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        Ok(Box::new(SyntheticBackend::new(p, 1e-6, seed)) as Box<dyn Backend>)
    })
}

fn start_server(npu_depth: usize, cpu_depth: usize) -> (Server, Arc<WindVE>) {
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth,
                cpu_depth,
                hetero: cpu_depth > 0,
                npu_workers: 1,
                cpu_workers: if cpu_depth > 0 { 1 } else { 0 },
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            if cpu_depth > 0 { vec![synth_factory(2)] } else { vec![] },
        )
        .unwrap(),
    );
    let server = Server::start("127.0.0.1:0", Arc::clone(&svc), Duration::from_secs(2)).unwrap();
    (server, svc)
}

fn request(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    // One-shot client: ask the keep-alive server to close so EOF frames
    // the response.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            format!(
                "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap();
    parse_response(&buf)
}

/// One-shot request that also returns the response head, for header
/// assertions (`Retry-After`, `Allow`, `Deprecation`).
fn request_with_head(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            format!(
                "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let head = raw.split("\r\n\r\n").next().unwrap_or("").to_string();
    let (status, rbody) = parse_response(&raw);
    (status, head, rbody)
}

/// Case-insensitive header lookup in a raw response head.
fn header(head: &str, name: &str) -> Option<String> {
    head.lines().find_map(|l| {
        let (k, v) = l.split_once(':')?;
        k.eq_ignore_ascii_case(name).then(|| v.trim().to_string())
    })
}

fn parse_response(raw: &str) -> (u16, String) {
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = raw.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

/// Read exactly one HTTP response (head + Content-Length-framed body)
/// off a stream that stays open — the keep-alive client side.
fn read_one_response(stream: &mut TcpStream) -> (u16, String, String) {
    let mut raw: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = raw.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-response: {:?}", String::from_utf8_lossy(&raw));
        raw.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(raw[..head_end].to_vec()).unwrap();
    let clen: usize = head
        .lines()
        .find_map(|l| {
            l.to_ascii_lowercase()
                .strip_prefix("content-length:")
                .map(|v| v.trim().parse().unwrap())
        })
        .expect("response must carry Content-Length");
    let mut body = raw[head_end + 4..].to_vec();
    while body.len() < clen {
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(clen);
    let status: u16 = head.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    (status, head, String::from_utf8(body).unwrap())
}

#[test]
fn healthz_responds_ok() {
    let (server, _svc) = start_server(8, 4);
    let (status, body) = request(server.addr(), "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(json::parse(&body).unwrap().get("ok").unwrap().as_bool(), Some(true));
    server.stop();
}

#[test]
fn embed_endpoint_returns_vectors_and_routes() {
    let (server, _svc) = start_server(8, 4);
    let (status, body) = request(
        server.addr(),
        "POST",
        "/v1/embed",
        r#"{"texts":["hello world","second query"]}"#,
    );
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    let emb = v.get("embeddings").unwrap().as_arr().unwrap();
    assert_eq!(emb.len(), 2);
    assert!(!emb[0].as_arr().unwrap().is_empty());
    let routes = v.get("routes").unwrap().as_arr().unwrap();
    assert_eq!(routes[0].as_str(), Some("NPU"));
    server.stop();
}

#[test]
fn single_text_form_accepted() {
    let (server, _svc) = start_server(4, 0);
    let (status, body) = request(server.addr(), "POST", "/v1/embed", r#"{"text":"solo"}"#);
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("embeddings").unwrap().as_arr().unwrap().len(), 1);
    server.stop();
}

#[test]
fn overload_returns_503_busy_with_retry_after() {
    // Depth 0: every submission is an Algorithm-1 BUSY.
    let (server, _svc) = start_server(0, 0);
    let (status, head, body) =
        request_with_head(server.addr(), "POST", "/v1/embed", r#"{"texts":["x"]}"#);
    assert_eq!(status, 503, "{body}");
    let v = json::parse(&body).unwrap();
    let err = v.get("error").unwrap();
    assert_eq!(err.get("code").unwrap().as_str(), Some("busy"));
    assert!(err.get("message").is_some(), "{body}");
    // Queue-occupancy-derived back-off hint, clamped to [1, 8] seconds.
    let retry: u64 = header(&head, "Retry-After")
        .expect("503 must carry Retry-After")
        .parse()
        .unwrap();
    assert!((1..=8).contains(&retry), "{retry}");
    server.stop();
}

#[test]
fn malformed_json_is_400() {
    let (server, _svc) = start_server(4, 0);
    let (status, _) = request(server.addr(), "POST", "/v1/embed", "{not json");
    assert_eq!(status, 400);
    let (status, _) = request(server.addr(), "POST", "/v1/embed", r#"{"nope":1}"#);
    assert_eq!(status, 400);
    server.stop();
}

#[test]
fn unknown_path_is_404() {
    let (server, _svc) = start_server(4, 0);
    let (status, _) = request(server.addr(), "GET", "/nope", "");
    assert_eq!(status, 404);
    server.stop();
}

#[test]
fn stats_reflect_traffic() {
    let (server, _svc) = start_server(8, 4);
    let _ = request(server.addr(), "POST", "/v1/embed", r#"{"texts":["a","b"]}"#);
    let (status, body) = request(server.addr(), "GET", "/stats", "");
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("npu_depth").unwrap().as_u64(), Some(8));
    assert!(v.get("routed_npu").unwrap().as_u64().unwrap() >= 2);
    assert_eq!(v.get("hetero").unwrap().as_bool(), Some(true));
    // NPU retrieval leg fields are surfaced (leg disabled by default).
    assert_eq!(v.get("npu_retrieve_cap").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("retrieve_npu_occupancy").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("embed_npu_occupancy").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("routed_retrieve_npu").unwrap().as_u64(), Some(0));
    // No retrieval index attached: poison recoveries report 0.
    assert_eq!(v.get("retrieval_poisoned_recoveries").unwrap().as_u64(), Some(0));
    let (_, mbody) = request(server.addr(), "GET", "/metrics", "");
    assert!(json::parse(&mbody).unwrap().get("service.accepted").is_some());
    server.stop();
}

/// The poisoning satellite end-to-end: a panicking writer on the
/// attached index must leave `/stats` serving (recovered reads), with
/// the recovery count surfaced for operators.
#[test]
fn stats_surface_poisoned_lock_recoveries() {
    use windve::devices::executor::RetrievalExecutor;
    use windve::testing::pseudo_embedding;

    let (server, svc) = start_server(4, 2);
    let exec = std::sync::Arc::new(RetrievalExecutor::flat(8));
    for i in 0..4u64 {
        exec.add(i, &pseudo_embedding(&format!("d{i}"), 8));
    }
    svc.attach_retrieval(std::sync::Arc::clone(&exec));
    // Poison the index lock: a mis-sized add panics inside the guard.
    let poisoner = std::sync::Arc::clone(&exec);
    assert!(std::thread::spawn(move || poisoner.add(9, &[1.0])).join().is_err());
    // Retrieval still answers (recovered read guard)…
    let hits = exec.search(&pseudo_embedding("d2", 8), 2);
    assert_eq!(hits[0].id, 2);
    // …and /stats reports the recovery.
    let (status, body) = request(server.addr(), "GET", "/stats", "");
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    assert!(v.get("retrieval_poisoned_recoveries").unwrap().as_u64().unwrap() >= 1);
    server.stop();
}

#[test]
fn concurrent_http_clients() {
    let (server, _svc) = start_server(32, 8);
    let addr = server.addr();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let (status, body) = request(
                    addr,
                    "POST",
                    "/v1/embed",
                    &format!(r#"{{"texts":["client {i} query"]}}"#),
                );
                assert!(status == 200 || status == 503, "{status} {body}");
                status
            })
        })
        .collect();
    let ok = handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .filter(|&s| s == 200)
        .count();
    assert!(ok >= 6, "most concurrent clients should succeed ({ok}/8)");
    server.stop();
}

/// Keep-alive satellite e2e: one connection serves several requests;
/// leftover bytes between them are preserved; the server advertises the
/// disposition it honors.
#[test]
fn ingest_keep_alive_serves_multiple_requests_per_connection() {
    let (server, _svc) = start_server(8, 4);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    for i in 0..3 {
        let body = format!("{{\"texts\":[\"keep alive {i}\"]}}");
        stream
            .write_all(
                format!(
                    "POST /v1/embed HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                    body.len()
                )
                .as_bytes(),
            )
            .unwrap();
        let (status, head, rbody) = read_one_response(&mut stream);
        assert_eq!(status, 200, "request {i}: {rbody}");
        assert!(
            head.to_ascii_lowercase().contains("connection: keep-alive"),
            "request {i} head: {head}"
        );
        let parsed = json::parse(&rbody).unwrap();
        assert!(!parsed.get("embeddings").unwrap().as_arr().unwrap().is_empty());
    }
    // An explicit close is honored.
    stream
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    let (status, head, _) = read_one_response(&mut stream);
    assert_eq!(status, 200);
    assert!(head.to_ascii_lowercase().contains("connection: close"));
    let mut rest = String::new();
    stream.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after Connection: close");
    server.stop();
}

fn start_ingest_server(
    npu_depth: usize,
    cpu_depth: usize,
) -> (Server, Arc<WindVE>, Arc<windve::devices::executor::RetrievalExecutor>) {
    use windve::devices::executor::RetrievalExecutor;
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth,
                cpu_depth,
                hetero: true,
                npu_workers: 1,
                cpu_workers: 1,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ingest_depth: 2,
                npu_ingest_depth: 4,
                ingest_low_water: 1.0,
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            vec![synth_factory(2)],
        )
        .unwrap(),
    );
    // SyntheticBackend emits 64-dim deterministic embeddings.
    let exec = Arc::new(RetrievalExecutor::flat(64));
    svc.attach_retrieval(Arc::clone(&exec));
    let server = Server::start("127.0.0.1:0", Arc::clone(&svc), Duration::from_secs(2)).unwrap();
    (server, svc, exec)
}

/// Fresh server: the status endpoint exists and reports zeros plus the
/// live corpus version.
#[test]
fn ingest_status_endpoint_reports_counters() {
    let (server, _svc, exec) = start_ingest_server(8, 4);
    exec.add(99, &[0.125f32; 64]); // unit vector: 64 · 0.125² = 1
    let (status, body) = request(server.addr(), "GET", "/v1/ingest/status", "");
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("docs_received").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("docs_indexed").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("active_streams").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("corpus_version").unwrap().as_u64(), Some(1));
    server.stop();
}

/// Shape errors don't kill the stream; parse errors abort it with a 400
/// and the connection closes (framing is unrecoverable).
#[test]
fn ingest_corpus_upload_reports_doc_failures_and_aborts_on_bad_json() {
    let (server, _svc, exec) = start_ingest_server(8, 4);
    // One good doc, one bad shape, one good doc.
    let ndjson = "{\"id\":1,\"text\":\"good one\"}\n{\"text\":\"no id\"}\n{\"id\":2,\"text\":\"good two\"}\n";
    let (status, body) = request_chunked(server.addr(), "/v1/corpus", ndjson, 11);
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("received").unwrap().as_u64(), Some(3));
    assert_eq!(v.get("indexed").unwrap().as_u64(), Some(2));
    assert_eq!(v.get("failed").unwrap().as_u64(), Some(1));
    assert_eq!(exec.len(), 2);
    // Malformed JSON aborts with a 400.
    let (status, body) = request_chunked(server.addr(), "/v1/corpus", "{\"id\":3,\"tex", 5);
    assert_eq!(status, 400, "{body}");
    assert_eq!(exec.len(), 2);
    server.stop();
}

/// Send `ndjson` as a chunked-transfer POST, slicing the body into
/// `chunk` - byte pieces (every escape/UTF-8/number seam gets exercised
/// somewhere in the stream).
fn request_chunked(
    addr: std::net::SocketAddr,
    path: &str,
    ndjson: &str,
    chunk: usize,
) -> (u16, String) {
    let mut raw = format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nTransfer-Encoding: chunked\r\n\r\n"
    )
    .into_bytes();
    for piece in ndjson.as_bytes().chunks(chunk.max(1)) {
        raw.extend_from_slice(format!("{:x}\r\n", piece.len()).as_bytes());
        raw.extend_from_slice(piece);
        raw.extend_from_slice(b"\r\n");
    }
    raw.extend_from_slice(b"0\r\n\r\n");
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(&raw).unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap();
    parse_response(&buf)
}

/// The tentpole acceptance scenario: ≥1k documents stream through a
/// chunked `POST /v1/corpus` into a LIVE server while an embed+retrieve
/// storm runs. Every document becomes retrievable (version-checked),
/// admission keeps every pool at or under its calibrated depth at every
/// probe, the parser never materializes the body, and `/stats`
/// reconciles exactly.
#[test]
fn ingest_chunked_upload_serves_queries_throughout() {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    let npu_depth = 16;
    let cpu_depth = 8;
    let (server, svc, exec) = start_ingest_server(npu_depth, cpu_depth);
    let n_docs = 1200u64;
    let mut ndjson = String::new();
    for i in 0..n_docs {
        ndjson.push_str(&format!(
            "{{\"id\":{i},\"text\":\"corpus document number {i} with some padding text\"}}\n"
        ));
    }
    let body_bytes = ndjson.len();

    // The serving storm: embed + retrieve traffic hammering the service
    // while the upload streams, with a depth probe at every round.
    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicU64::new(0));
    let storm: Vec<_> = (0..3)
        .map(|t| {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    i += 1;
                    let _ = svc.embed_blocking(
                        format!("storm embed {t}-{i}"),
                        Duration::from_secs(5),
                    );
                    let _ = svc.retrieve_blocking(
                        &[format!("storm retrieve {t}-{i}")],
                        3,
                        Duration::from_secs(5),
                    );
                    // The live depth probe: admission keeps every pool
                    // at or under its calibrated depth, storm + upload
                    // combined.
                    let qm = svc.queue_manager();
                    assert!(qm.cpu_occupancy() <= cpu_depth);
                    assert!(qm.npu_occupancy() <= npu_depth);
                    served.fetch_add(1, Ordering::Relaxed);
                }
            })
        })
        .collect();

    // Stream the upload in 173-byte client chunks (doc boundaries land
    // everywhere inside chunk frames).
    let (status, resp) = request_chunked(server.addr(), "/v1/corpus", &ndjson, 173);
    stop.store(true, Ordering::Relaxed);
    for h in storm {
        h.join().unwrap();
    }
    assert_eq!(status, 200, "{resp}");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("received").unwrap().as_u64(), Some(n_docs), "{resp}");
    assert_eq!(v.get("indexed").unwrap().as_u64(), Some(n_docs), "{resp}");
    assert_eq!(v.get("failed").unwrap().as_u64(), Some(0), "{resp}");
    assert!(served.load(Ordering::Relaxed) > 0, "storm never got service");

    // Version-checked completeness: the corpus advanced by exactly the
    // ingested rows and holds them all.
    assert_eq!(exec.len(), n_docs as usize);
    assert_eq!(exec.version(), n_docs);
    assert_eq!(v.get("corpus_version").unwrap().as_u64(), Some(n_docs));

    // The body was never materialized: the parser's peak resident chunk
    // is bounded by the server's socket-read granularity (16 KiB), far
    // under the body.
    let peak = v.get("peak_chunk_bytes").unwrap().as_u64().unwrap() as usize;
    assert!(peak > 0 && peak <= 16 * 1024, "peak {peak}");
    assert!(peak < body_bytes / 3, "peak {peak} vs body {body_bytes}");

    // Every document is retrievable through the serving path (sampled),
    // with its own id on top.
    for i in (0..n_docs).step_by(97) {
        let text = format!("corpus document number {i} with some padding text");
        let hits = svc.retrieve_blocking(&[text], 1, Duration::from_secs(5));
        assert_eq!(hits[0].as_ref().unwrap()[0].id, i, "doc {i}");
    }

    // /stats reconciliation: drained occupancies, clean release
    // accounting, and exactly one successful ingest admission per doc.
    std::thread::sleep(Duration::from_millis(100));
    let (status, stats) = request(server.addr(), "GET", "/stats", "");
    assert_eq!(status, 200);
    let s = json::parse(&stats).unwrap();
    for f in [
        "cpu_occupancy",
        "npu_occupancy",
        "ingest_cpu_occupancy",
        "ingest_npu_occupancy",
        "retrieve_cpu_occupancy",
        "retrieve_npu_occupancy",
        "bad_releases",
    ] {
        assert_eq!(s.get(f).unwrap().as_u64(), Some(0), "{f}: {stats}");
    }
    let routed = s.get("routed_ingest").unwrap().as_u64().unwrap()
        + s.get("routed_ingest_npu").unwrap().as_u64().unwrap();
    assert_eq!(routed, n_docs, "{stats}");
    // The status endpoint agrees with the upload response.
    let (_, st) = request(server.addr(), "GET", "/v1/ingest/status", "");
    let st = json::parse(&st).unwrap();
    assert_eq!(st.get("docs_indexed").unwrap().as_u64(), Some(n_docs));
    assert_eq!(st.get("streams_completed").unwrap().as_u64(), Some(1));
    assert_eq!(st.get("active_streams").unwrap().as_u64(), Some(0));
    server.stop();
}

// ---------------------------------------------------------------------------
// Durable corpus lifecycle over the wire.

fn start_durable_server() -> (
    Server,
    Arc<WindVE>,
    Arc<windve::devices::executor::RetrievalExecutor>,
    Arc<windve::durability::DurableStore>,
    Arc<windve::durability::FaultFs>,
) {
    use windve::durability::{DurabilityOptions, DurableStore, FaultFs, Fs};
    use windve::testing::pseudo_embedding;
    use windve::vecstore::FlatIndex;

    let (server, svc, _detached) = start_ingest_server(8, 4);
    let fs = Arc::new(FaultFs::new());
    let dynfs: Arc<dyn Fs> = fs.clone();
    // SyntheticBackend emits 64-dim embeddings; the replay embedder is
    // only exercised when a WAL tail exists.
    let (store, exec, _report) = DurableStore::recover(
        dynfs,
        std::path::Path::new("/srv"),
        DurabilityOptions::default(),
        || Box::new(FlatIndex::new(64)),
        |text| Ok(pseudo_embedding(text, 64)),
    )
    .unwrap();
    svc.attach_retrieval(Arc::clone(&exec));
    svc.attach_durability(Arc::clone(&store));
    (server, svc, exec, store, fs)
}

/// `DELETE /v1/corpus/{id}` and `POST /v1/corpus/snapshot` end to end:
/// uploads WAL-log before acking, deletes tombstone durably (unknown ids
/// still log), the snapshot truncates the WAL, and `/stats` surfaces the
/// durability block.
#[test]
fn corpus_delete_and_snapshot_endpoints_are_durable() {
    let (server, _svc, exec, store, fs) = start_durable_server();
    let mut ndjson = String::new();
    for i in 0..6u64 {
        ndjson.push_str(&format!("{{\"id\":{i},\"text\":\"durable doc {i}\"}}\n"));
    }
    let (status, body) = request_chunked(server.addr(), "/v1/corpus", &ndjson, 32);
    assert_eq!(status, 200, "{body}");
    assert_eq!(json::parse(&body).unwrap().get("indexed").unwrap().as_u64(), Some(6));
    assert_eq!(store.stats().committed_seq, 6, "uploads were WAL-logged before the ack");

    // Durable delete: tombstone + version bump; repeat delete of the
    // same id is a success that removes nothing (but still logs).
    let (status, body) = request(server.addr(), "DELETE", "/v1/corpus/3", "");
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("removed").unwrap().as_u64(), Some(1));
    assert!(v.get("corpus_version").unwrap().as_u64().unwrap() >= 7);
    let (status, body) = request(server.addr(), "DELETE", "/v1/corpus/3", "");
    assert_eq!(status, 200);
    assert_eq!(json::parse(&body).unwrap().get("removed").unwrap().as_u64(), Some(0));
    let (status, _) = request(server.addr(), "DELETE", "/v1/corpus/not-a-number", "");
    assert_eq!(status, 400);
    assert_eq!(exec.len(), 5);
    assert_eq!(store.stats().committed_seq, 8, "6 upserts + 2 delete records");

    // /stats carries the durability block while a store is attached.
    let (_, stats) = request(server.addr(), "GET", "/stats", "");
    let s = json::parse(&stats).unwrap();
    let d = s.get("durability").expect("durability block in /stats");
    assert_eq!(d.get("committed_seq").unwrap().as_u64(), Some(8));
    assert!(d.get("wal_bytes").unwrap().as_u64().unwrap() > 0);

    // Checkpoint over the wire: watermark covers everything, WAL gone.
    let (status, body) = request(server.addr(), "POST", "/v1/corpus/snapshot", "");
    assert_eq!(status, 200, "{body}");
    assert_eq!(json::parse(&body).unwrap().get("watermark").unwrap().as_u64(), Some(8));
    let st = store.stats();
    assert_eq!(st.wal_segments, 0, "WAL truncated behind the snapshot");
    assert_eq!(st.snapshots_written, 1);
    server.stop();

    // Crash + offline recovery: the snapshot alone restores the corpus,
    // with the deleted doc still gone.
    use windve::durability::{DurabilityOptions, DurableStore, FaultPlan, Fs};
    use windve::vecstore::FlatIndex;
    fs.crash_now();
    fs.restart(FaultPlan::default());
    let dynfs: Arc<dyn Fs> = fs.clone();
    let (_, exec2, report) = DurableStore::recover(
        dynfs,
        std::path::Path::new("/srv"),
        DurabilityOptions::default(),
        || Box::new(FlatIndex::new(64)),
        |_| anyhow::bail!("no tail to replay"),
    )
    .unwrap();
    assert!(report.from_snapshot);
    assert_eq!(report.replayed, 0);
    assert_eq!(exec2.len(), 5);
    let (ids, _, _) = exec2.export_corpus().unwrap();
    assert!(!ids.contains(&3), "deleted id resurrected by recovery");
}

/// Without a durable store attached, the snapshot endpoint reports a
/// server error instead of pretending to checkpoint.
#[test]
fn snapshot_without_store_is_500() {
    let (server, _svc) = start_server(4, 0);
    let (status, body) = request(server.addr(), "POST", "/v1/corpus/snapshot", "");
    assert_eq!(status, 500, "{body}");
    server.stop();
}

// ---------------------------------------------------------------------------
// Slow-loris guard over the wire.

/// A client that sends half a request head and stalls gets a 408 and a
/// closed connection once the per-request budget expires — while an
/// idle keep-alive connection (no bytes sent) is left alone and can
/// still issue a request afterwards.
#[test]
fn slow_loris_partial_head_gets_408_idle_connection_survives() {
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 4,
                cpu_depth: 0,
                hetero: false,
                npu_workers: 1,
                cpu_workers: 0,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            vec![],
        )
        .unwrap(),
    );
    let server = Server::start_with_deadline(
        "127.0.0.1:0",
        Arc::clone(&svc),
        Duration::from_secs(2),
        Duration::from_millis(300),
    )
    .unwrap();

    // The loris: half a head, then silence. The budget armed on the
    // first byte; the server must answer 408 and close.
    let mut loris = TcpStream::connect(server.addr()).unwrap();
    loris.write_all(b"POST /v1/embed HTTP/1.1\r\nHost: t\r\n").unwrap();
    let mut raw = String::new();
    loris.read_to_string(&mut raw).unwrap(); // returns only on server close
    let (status, _) = parse_response(&raw);
    assert_eq!(status, 408, "{raw}");

    // The idler: a connection that has sent nothing is not on the clock.
    let mut idler = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(Duration::from_millis(500));
    idler
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    idler.read_to_string(&mut raw).unwrap();
    let (status, _) = parse_response(&raw);
    assert_eq!(status, 200, "idle keep-alive killed: {raw}");
    server.stop();
}

// ---------------------------------------------------------------------------
// v1 API contract: error envelope, 405 + Allow, deprecation aliases.

fn envelope_code(body: &str) -> String {
    json::parse(body)
        .unwrap_or_else(|e| panic!("error body must be JSON ({e}): {body:?}"))
        .get("error")
        .unwrap_or_else(|| panic!("missing error object: {body}"))
        .get("code")
        .and_then(|c| c.as_str().map(str::to_string))
        .unwrap_or_else(|| panic!("missing error.code: {body}"))
}

/// Every documented error path answers with the versioned envelope
/// `{"error":{"code","message"}}` and the documented code (docs/API.md).
#[test]
fn error_responses_use_the_v1_envelope() {
    let (server, _svc) = start_server(4, 0);
    let addr = server.addr();

    // 404 — no such route.
    let (status, body) = request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    assert_eq!(envelope_code(&body), "not_found");

    // 405 — known path, wrong method, with the Allow union.
    let (status, head, body) = request_with_head(addr, "PUT", "/v1/embed", "");
    assert_eq!(status, 405, "{body}");
    assert_eq!(envelope_code(&body), "method_not_allowed");
    assert_eq!(header(&head, "Allow").as_deref(), Some("POST"));
    let (status, head, _) = request_with_head(addr, "PUT", "/v1/corpus/snapshot", "");
    assert_eq!(status, 405);
    let allow = header(&head, "Allow").unwrap();
    assert!(allow.contains("POST") && allow.contains("DELETE"), "{allow}");

    // 400 invalid_request — malformed body.
    let (status, body) = request(addr, "POST", "/v1/embed", "{not json");
    assert_eq!(status, 400);
    assert_eq!(envelope_code(&body), "invalid_request");

    // 400 invalid_id — the typed-param bugfix: trailing junk on the id
    // is consistently a 400, never a 404.
    for junk in ["3junk", "not-a-number", "-1"] {
        let (status, body) = request(addr, "DELETE", &format!("/v1/corpus/{junk}"), "");
        assert_eq!(status, 400, "{junk}: {body}");
        assert_eq!(envelope_code(&body), "invalid_id", "{junk}");
    }

    // 500 internal — snapshot without a durable store.
    let (status, body) = request(addr, "POST", "/v1/corpus/snapshot", "");
    assert_eq!(status, 500);
    assert_eq!(envelope_code(&body), "internal");
    server.stop();
}

/// 413 — a declared body over the limit is refused from the headers
/// alone (the body is never read), with the envelope and a close.
#[test]
fn oversized_declared_body_is_413() {
    let (server, _svc) = start_server(4, 0);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    // Declare 8 MiB but send nothing: the server must answer from the
    // preflight, not wait for the body.
    stream
        .write_all(b"POST /v1/embed HTTP/1.1\r\nHost: t\r\nContent-Length: 8388608\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (status, body) = parse_response(&raw);
    assert_eq!(status, 413, "{raw}");
    assert_eq!(envelope_code(&body), "payload_too_large");
    server.stop();
}

/// 408 carries the envelope too (the slow-loris path).
#[test]
fn request_timeout_envelope() {
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 4,
                cpu_depth: 0,
                hetero: false,
                npu_workers: 1,
                cpu_workers: 0,
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            vec![],
        )
        .unwrap(),
    );
    let server = Server::start_with_deadline(
        "127.0.0.1:0",
        Arc::clone(&svc),
        Duration::from_secs(2),
        Duration::from_millis(200),
    )
    .unwrap();
    let mut loris = TcpStream::connect(server.addr()).unwrap();
    loris.write_all(b"GET /v1/healthz HTTP/1.1\r\n").unwrap();
    let mut raw = String::new();
    loris.read_to_string(&mut raw).unwrap();
    let (status, body) = parse_response(&raw);
    assert_eq!(status, 408, "{raw}");
    assert_eq!(envelope_code(&body), "request_timeout");
    server.stop();
}

/// `/healthz`, `/metrics`, `/stats` keep serving as deprecated aliases
/// of their `/v1/` homes — same bodies, plus a `Deprecation` header.
/// The canonical paths carry no such header.
#[test]
fn deprecated_aliases_serve_with_deprecation_header() {
    let (server, _svc) = start_server(4, 0);
    let addr = server.addr();
    for (alias, canonical) in
        [("/healthz", "/v1/healthz"), ("/metrics", "/v1/metrics"), ("/stats", "/v1/stats")]
    {
        let (status, head, body) = request_with_head(addr, "GET", alias, "");
        assert_eq!(status, 200, "{alias}: {body}");
        assert_eq!(header(&head, "Deprecation").as_deref(), Some("true"), "{alias}");
        let (status, vhead, vbody) = request_with_head(addr, "GET", canonical, "");
        assert_eq!(status, 200, "{canonical}: {vbody}");
        assert!(header(&vhead, "Deprecation").is_none(), "{canonical} must not be deprecated");
        // Alias and canonical serve the same document shape.
        let a = json::parse(&body).unwrap();
        let c = json::parse(&vbody).unwrap();
        match alias {
            "/healthz" => {
                assert_eq!(a.get("ok").unwrap().as_bool(), c.get("ok").unwrap().as_bool())
            }
            "/stats" => {
                assert_eq!(
                    a.get("npu_depth").unwrap().as_u64(),
                    c.get("npu_depth").unwrap().as_u64()
                )
            }
            _ => {
                assert_eq!(a.get("service.accepted").is_some(), c.get("service.accepted").is_some())
            }
        }
    }
    server.stop();
}

// ---------------------------------------------------------------------------
// Observability: request tracing, stage quantiles, Prometheus exposition.

/// One-shot request with extra raw header lines (each must end in
/// `\r\n`), for content-negotiation tests the fixed-header helpers
/// can't express.
fn request_with_headers(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    extra: &str,
    body: &str,
) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            format!(
                "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n{extra}Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let head = raw.split("\r\n\r\n").next().unwrap_or("").to_string();
    let (status, rbody) = parse_response(&raw);
    (status, head, rbody)
}

/// The tentpole acceptance scenario: one `POST /v1/search` produces a
/// complete span tree — queue_wait → batch_form → embed → scan → merge
/// → respond, all under the `X-Trace-Id` the response carried — visible
/// through `GET /v1/trace`, with the stage durations summing to no more
/// than the client-observed wall time (the stages are disjoint slices
/// of the request's lifetime).
#[test]
fn search_serves_complete_span_tree_via_trace_endpoint() {
    use windve::testing::pseudo_embedding;

    let (server, _svc, exec) = start_ingest_server(8, 4);
    for i in 0..8u64 {
        exec.add(i, &pseudo_embedding(&format!("span doc {i}"), 64));
    }
    let t0 = std::time::Instant::now();
    let (status, head, body) = request_with_head(
        server.addr(),
        "POST",
        "/v1/search",
        r#"{"queries":["what is a span tree"],"k":3}"#,
    );
    let wall_ns = t0.elapsed().as_nanos() as u64;
    assert_eq!(status, 200, "{body}");
    let trace_id: u64 = header(&head, "X-Trace-Id")
        .expect("traced response must carry X-Trace-Id")
        .parse()
        .unwrap();
    assert!(trace_id != 0);
    let v = json::parse(&body).unwrap();
    let results = v.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 1, "{body}");
    assert!(!results[0].get("hits").unwrap().as_arr().unwrap().is_empty(), "{body}");

    // The respond span lands just after the response bytes flush, so
    // poll briefly rather than race the server's last store.
    let want = ["queue_wait", "batch_form", "embed", "scan", "merge", "respond"];
    let mut spans: Vec<json::Json> = Vec::new();
    for _ in 0..50 {
        let (status, tbody) = request(server.addr(), "GET", "/v1/trace", "");
        assert_eq!(status, 200, "{tbody}");
        let t = json::parse(&tbody).unwrap();
        assert_eq!(t.get("enabled").unwrap().as_bool(), Some(true));
        spans = t
            .get("spans")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|s| s.get("trace_id").and_then(|x| x.as_u64()) == Some(trace_id))
            .cloned()
            .collect();
        let have =
            |st: &str| spans.iter().any(|s| s.get("stage").and_then(|x| x.as_str()) == Some(st));
        if want.iter().all(|st| have(st)) {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    for st in want {
        assert!(
            spans.iter().any(|s| s.get("stage").and_then(|x| x.as_str()) == Some(st)),
            "stage {st} missing from span tree: {spans:?}"
        );
    }
    // Labels hold the class/route/codec projection the schema promises.
    for s in &spans {
        match s.get("stage").and_then(|x| x.as_str()).unwrap() {
            "scan" => {
                assert_eq!(s.get("class").unwrap().as_str(), Some("retrieve"));
                assert_eq!(s.get("codec").unwrap().as_str(), Some("f32"));
            }
            "respond" => assert_eq!(s.get("route").unwrap().as_str(), Some("all")),
            _ => assert!(matches!(s.get("route").unwrap().as_str(), Some("npu" | "cpu"))),
        }
    }
    // Stage durations are disjoint slices of the request: their sum is
    // positive and bounded by the client-observed wall time.
    let sum: u64 = spans.iter().map(|s| s.get("dur_ns").unwrap().as_u64().unwrap()).sum();
    assert!(sum > 0, "{spans:?}");
    assert!(sum <= wall_ns, "span sum {sum} ns exceeds wall {wall_ns} ns");
    server.stop();
}

/// Content negotiation on `/v1/metrics`: `Accept: text/plain` serves a
/// parseable Prometheus 0.0.4 exposition with the stage-duration family
/// populated after traffic, while the default (no Accept) stays JSON.
#[test]
fn metrics_content_negotiation_serves_prometheus_text() {
    let (server, _svc) = start_server(8, 4);
    let (status, body) =
        request(server.addr(), "POST", "/v1/embed", r#"{"texts":["prom a","prom b"]}"#);
    assert_eq!(status, 200, "{body}");

    let (status, head, text) = request_with_headers(
        server.addr(),
        "GET",
        "/v1/metrics",
        "Accept: text/plain\r\n",
        "",
    );
    assert_eq!(status, 200, "{text}");
    let ctype = header(&head, "Content-Type").unwrap();
    assert!(ctype.starts_with("text/plain"), "{ctype}");
    assert!(ctype.contains("version=0.0.4"), "{ctype}");
    assert!(text.contains("# TYPE windve_service_accepted counter\n"), "{text}");
    assert!(text.contains("windve_stage_duration_ns{stage=\"embed\",class=\"embed\","), "{text}");
    // Every sample line is `name[{labels}] value` — two tokens once the
    // label block is stripped; that is what a scraper parses.
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let stripped = match (line.find('{'), line.rfind('}')) {
            (Some(a), Some(b)) if a < b => format!("{}{}", &line[..a], &line[b + 1..]),
            _ => line.to_string(),
        };
        assert_eq!(stripped.split_whitespace().count(), 2, "unparseable line: {line}");
    }

    // The historic contract survives negotiation: no Accept → JSON.
    let (status, jbody) = request(server.addr(), "GET", "/v1/metrics", "");
    assert_eq!(status, 200);
    assert!(json::parse(&jbody).unwrap().get("service.accepted").is_some(), "{jbody}");
    server.stop();
}

fn start_slo_server(slo: Duration) -> (Server, Arc<WindVE>) {
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                npu_workers: 1,
                cpu_workers: 1,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                slo: Some(slo),
                slo_window: 16,
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            vec![synth_factory(2)],
        )
        .unwrap(),
    );
    let server = Server::start("127.0.0.1:0", Arc::clone(&svc), Duration::from_secs(2)).unwrap();
    (server, svc)
}

/// `/v1/stats` carries the labeled stage-quantile block and the live
/// SLO block once traffic has flowed: per-stage p50 ≤ p95 ≤ p99 under
/// schema names, attainment/breached/recommended-depth from the
/// governor.
#[test]
fn stats_surface_stage_quantiles_and_slo_block() {
    let (server, _svc) = start_slo_server(Duration::from_millis(250));
    for i in 0..3 {
        let (status, body) = request(
            server.addr(),
            "POST",
            "/v1/embed",
            &format!(r#"{{"texts":["slo probe {i}a","slo probe {i}b"]}}"#),
        );
        assert_eq!(status, 200, "{body}");
    }
    let (status, body) = request(server.addr(), "GET", "/v1/stats", "");
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();

    let stages = v.get("stages").expect("stages block in /v1/stats").as_obj().unwrap();
    assert!(!stages.is_empty(), "{body}");
    let mut saw_embed = false;
    for (name, q) in stages {
        assert!(name.starts_with("trace."), "{name}");
        assert!(q.get("count").unwrap().as_u64().unwrap() > 0, "{name}");
        let p50 = q.get("p50_ns").unwrap().as_u64().unwrap();
        let p95 = q.get("p95_ns").unwrap().as_u64().unwrap();
        let p99 = q.get("p99_ns").unwrap().as_u64().unwrap();
        assert!(p50 <= p95 && p95 <= p99, "{name}: {p50} {p95} {p99}");
        saw_embed |= name.starts_with("trace.embed.embed.");
    }
    assert!(saw_embed, "no embed stage series after embed traffic: {body}");

    let slo = v.get("slo").expect("slo block in /v1/stats");
    assert_eq!(slo.get("slo_ms").unwrap().as_f64(), Some(250.0), "{body}");
    assert!(slo.get("samples").unwrap().as_u64().unwrap() >= 3, "{body}");
    let att = slo.get("attainment").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&att), "{att}");
    assert!(slo.get("breached").unwrap().as_bool().is_some(), "{body}");
    assert!(slo.get("recommended_npu_depth").is_some(), "{body}");
    assert!(slo.get("retunes").unwrap().as_u64().is_some(), "{body}");
    server.stop();
}

/// `trace_capacity: 0` is the untraced baseline: no `X-Trace-Id`, and
/// `/v1/trace` reports tracing disabled instead of an empty lie.
#[test]
fn trace_capacity_zero_disables_tracing() {
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth: 4,
                cpu_depth: 0,
                hetero: false,
                npu_workers: 1,
                cpu_workers: 0,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                trace_capacity: 0,
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            vec![],
        )
        .unwrap(),
    );
    let server = Server::start("127.0.0.1:0", Arc::clone(&svc), Duration::from_secs(2)).unwrap();
    let (status, head, body) =
        request_with_head(server.addr(), "POST", "/v1/embed", r#"{"texts":["untraced"]}"#);
    assert_eq!(status, 200, "{body}");
    assert!(header(&head, "X-Trace-Id").is_none(), "untraced response carried a trace id");
    let (status, tbody) = request(server.addr(), "GET", "/v1/trace", "");
    assert_eq!(status, 200, "{tbody}");
    let t = json::parse(&tbody).unwrap();
    assert_eq!(t.get("enabled").unwrap().as_bool(), Some(false));
    assert!(t.get("spans").unwrap().as_arr().unwrap().is_empty());
    server.stop();
}
