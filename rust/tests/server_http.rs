//! HTTP front-end integration: a live server over a synthetic-backend
//! service, exercised with a raw TCP client (no HTTP client crate).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::{ServiceConfig, WindVE};
use windve::devices::executor::{Backend, SyntheticBackend};
use windve::devices::profile::DeviceProfile;
use windve::server::Server;
use windve::util::json;

fn synth_factory(seed: u64) -> BackendFactory {
    Box::new(move || {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        Ok(Box::new(SyntheticBackend::new(p, 1e-6, seed)) as Box<dyn Backend>)
    })
}

fn start_server(npu_depth: usize, cpu_depth: usize) -> (Server, Arc<WindVE>) {
    let svc = Arc::new(
        WindVE::start(
            ServiceConfig {
                npu_depth,
                cpu_depth,
                hetero: cpu_depth > 0,
                npu_workers: 1,
                cpu_workers: if cpu_depth > 0 { 1 } else { 0 },
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![synth_factory(1)],
            if cpu_depth > 0 { vec![synth_factory(2)] } else { vec![] },
        )
        .unwrap(),
    );
    let server = Server::start("127.0.0.1:0", Arc::clone(&svc), Duration::from_secs(2)).unwrap();
    (server, svc)
}

fn request(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            format!(
                "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap();
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

#[test]
fn healthz_responds_ok() {
    let (server, _svc) = start_server(8, 4);
    let (status, body) = request(server.addr(), "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(json::parse(&body).unwrap().get("ok").unwrap().as_bool(), Some(true));
    server.stop();
}

#[test]
fn embed_endpoint_returns_vectors_and_routes() {
    let (server, _svc) = start_server(8, 4);
    let (status, body) = request(
        server.addr(),
        "POST",
        "/v1/embed",
        r#"{"texts":["hello world","second query"]}"#,
    );
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    let emb = v.get("embeddings").unwrap().as_arr().unwrap();
    assert_eq!(emb.len(), 2);
    assert!(!emb[0].as_arr().unwrap().is_empty());
    let routes = v.get("routes").unwrap().as_arr().unwrap();
    assert_eq!(routes[0].as_str(), Some("NPU"));
    server.stop();
}

#[test]
fn single_text_form_accepted() {
    let (server, _svc) = start_server(4, 0);
    let (status, body) = request(server.addr(), "POST", "/v1/embed", r#"{"text":"solo"}"#);
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("embeddings").unwrap().as_arr().unwrap().len(), 1);
    server.stop();
}

#[test]
fn overload_returns_503_busy() {
    // Depth 0: every submission is an Algorithm-1 BUSY.
    let (server, _svc) = start_server(0, 0);
    let (status, body) = request(server.addr(), "POST", "/v1/embed", r#"{"texts":["x"]}"#);
    assert_eq!(status, 503, "{body}");
    assert_eq!(
        json::parse(&body).unwrap().get("error").unwrap().as_str(),
        Some("busy")
    );
    server.stop();
}

#[test]
fn malformed_json_is_400() {
    let (server, _svc) = start_server(4, 0);
    let (status, _) = request(server.addr(), "POST", "/v1/embed", "{not json");
    assert_eq!(status, 400);
    let (status, _) = request(server.addr(), "POST", "/v1/embed", r#"{"nope":1}"#);
    assert_eq!(status, 400);
    server.stop();
}

#[test]
fn unknown_path_is_404() {
    let (server, _svc) = start_server(4, 0);
    let (status, _) = request(server.addr(), "GET", "/nope", "");
    assert_eq!(status, 404);
    server.stop();
}

#[test]
fn stats_reflect_traffic() {
    let (server, _svc) = start_server(8, 4);
    let _ = request(server.addr(), "POST", "/v1/embed", r#"{"texts":["a","b"]}"#);
    let (status, body) = request(server.addr(), "GET", "/stats", "");
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("npu_depth").unwrap().as_u64(), Some(8));
    assert!(v.get("routed_npu").unwrap().as_u64().unwrap() >= 2);
    assert_eq!(v.get("hetero").unwrap().as_bool(), Some(true));
    // NPU retrieval leg fields are surfaced (leg disabled by default).
    assert_eq!(v.get("npu_retrieve_cap").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("retrieve_npu_occupancy").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("embed_npu_occupancy").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("routed_retrieve_npu").unwrap().as_u64(), Some(0));
    // No retrieval index attached: poison recoveries report 0.
    assert_eq!(v.get("retrieval_poisoned_recoveries").unwrap().as_u64(), Some(0));
    let (_, mbody) = request(server.addr(), "GET", "/metrics", "");
    assert!(json::parse(&mbody).unwrap().get("service.accepted").is_some());
    server.stop();
}

/// The poisoning satellite end-to-end: a panicking writer on the
/// attached index must leave `/stats` serving (recovered reads), with
/// the recovery count surfaced for operators.
#[test]
fn stats_surface_poisoned_lock_recoveries() {
    use windve::devices::executor::RetrievalExecutor;
    use windve::testing::pseudo_embedding;

    let (server, svc) = start_server(4, 2);
    let exec = std::sync::Arc::new(RetrievalExecutor::flat(8));
    for i in 0..4u64 {
        exec.add(i, &pseudo_embedding(&format!("d{i}"), 8));
    }
    svc.attach_retrieval(std::sync::Arc::clone(&exec));
    // Poison the index lock: a mis-sized add panics inside the guard.
    let poisoner = std::sync::Arc::clone(&exec);
    assert!(std::thread::spawn(move || poisoner.add(9, &[1.0])).join().is_err());
    // Retrieval still answers (recovered read guard)…
    let hits = exec.search(&pseudo_embedding("d2", 8), 2);
    assert_eq!(hits[0].id, 2);
    // …and /stats reports the recovery.
    let (status, body) = request(server.addr(), "GET", "/stats", "");
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    assert!(v.get("retrieval_poisoned_recoveries").unwrap().as_u64().unwrap() >= 1);
    server.stop();
}

#[test]
fn concurrent_http_clients() {
    let (server, _svc) = start_server(32, 8);
    let addr = server.addr();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let (status, body) = request(
                    addr,
                    "POST",
                    "/v1/embed",
                    &format!(r#"{{"texts":["client {i} query"]}}"#),
                );
                assert!(status == 200 || status == 503, "{status} {body}");
                status
            })
        })
        .collect();
    let ok = handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .filter(|&s| s == 200)
        .count();
    assert!(ok >= 6, "most concurrent clients should succeed ({ok}/8)");
    server.stop();
}
