//! Repo-local static analysis: `cargo xtask lint`.
//!
//! Four rules over `rust/src/**/*.rs` (test modules excluded), all
//! enforced to **zero findings** in CI (the `analysis` job):
//!
//! 1. **safety-comment** — every `unsafe { .. }` block (and `unsafe
//!    impl`) carries a `// SAFETY:` comment on the same line or in the
//!    comment run directly above it, stating why the operation is sound.
//! 2. **atomic-ordering** — `Ordering::SeqCst` is banned (it papers over
//!    not knowing the protocol; every handshake here is expressible with
//!    acquire/release) and `Ordering::Relaxed` is confined to an
//!    allowlist of files whose relaxed uses are monotonic stats counters
//!    (justified in [`RELAXED_ALLOWLIST`]). One-off exceptions carry an
//!    `// ordering:` comment at the site explaining the choice.
//! 3. **hot-path-unwrap** — no `.unwrap()` / `.expect()` in
//!    `src/server/` or `src/coordinator/` outside `#[cfg(test)]`: a
//!    panic there poisons locks under live traffic. Deliberate uses
//!    carry `// lint:allow(unwrap-expect): <why>` at the site.
//! 4. **std-sync-import** — modules migrated onto the `cfg(loom)` shim
//!    (`crate::util::sync`) must not re-import `std::sync` primitives
//!    the shim wraps, or the loom models silently stop covering them.
//!    `Arc`/`mpsc`/`PoisonError`/`LockResult` stay allowed: loom drives
//!    schedules through locks and atomics, not through those.
//!
//! The checker parses with `syn` (comments are invisible to the AST, so
//! marker comments are matched textually against the span's source
//! lines). It is deliberately file-local and fast — no type resolution,
//! no macro expansion — which keeps it honest: anything subtler than
//! these rules belongs in loom/Miri, not here.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use syn::visit::{self, Visit};

/// Files whose `Ordering::Relaxed` uses are allowed wholesale, with the
/// written justification the lint demands. Keep this list *short* and
/// the justifications true — a new entry needs both.
const RELAXED_ALLOWLIST: &[(&str, &str)] = &[
    (
        "src/coordinator/queue_manager.rs",
        "admission stats are monotonic counters and CAS seed loads; the \
         authoritative edges are the AcqRel compare-exchanges, documented \
         in the module header and exhaustively checked by the loom suite",
    ),
    (
        "src/coordinator/balancer.rs",
        "round-robin tick and load gauges: approximate by design, no \
         other memory is published through them",
    ),
    (
        "src/devices/executor.rs",
        "poisoned_recoveries is a monotonic diagnostic counter; the \
         index version/mirror handshake itself uses Release bumps and \
         Acquire reads",
    ),
    (
        "src/runtime/npu_scan.rs",
        "device_failures is a monotonic diagnostic counter feeding the \
         fallback decision; exactness is not required",
    ),
    (
        "src/metrics/histogram.rs",
        "lock-free histogram cells: per-cell counts are independent \
         monotonic counters, snapshots tolerate torn totals by design",
    ),
    (
        "src/metrics/registry.rs",
        "metric counters are monotonic and publish no other memory",
    ),
    (
        "src/vecstore/kernels.rs",
        "SIMD dispatch cache: idempotent detection result, any thread \
         recomputing it stores the same value",
    ),
    (
        "src/durability/mod.rs",
        "WAL stats are monotonic counters; durability ordering comes \
         from fsync, not from these",
    ),
    (
        "src/ingest/pipeline.rs",
        "ingest stats merge monotonic counters and maxes; readers \
         tolerate torn snapshots by design",
    ),
];

/// Shim-migrated modules (rule 4). Everything the loom models exercise
/// must route its sync primitives through `crate::util::sync`.
const MIGRATED_MODULES: &[&str] = &[
    "src/coordinator/queue_manager.rs",
    "src/coordinator/cache.rs",
    "src/devices/executor.rs",
    "src/metrics/trace.rs",
    "src/metrics/histogram.rs",
    "src/metrics/registry.rs",
    "src/metrics/slo.rs",
];

/// `std::sync` leaves that remain fine in migrated modules: loom swaps
/// scheduling primitives, not ownership or error types.
const ALLOWED_STD_SYNC: &[&str] = &["Arc", "Weak", "mpsc", "PoisonError", "LockResult", "TryLockError"];

/// Directories where a panic unwinds under live traffic (rule 3).
const HOT_PATH_DIRS: &[&str] = &["src/server/", "src/coordinator/"];

/// How far above a span the marker comment may sit: the contiguous run
/// of comment/attribute/blank lines directly above it, capped here so a
/// marker can't act at a distance.
const MARKER_LOOKBACK: usize = 12;

#[derive(Debug)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn main() {
    let mode = std::env::args().nth(1);
    let code = match mode.as_deref() {
        Some("lint") => match lint_tree() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("{e:#}");
                1
            }
        },
        _ => {
            eprintln!("usage: cargo xtask lint");
            2
        }
    };
    std::process::exit(code);
}

fn lint_tree() -> Result<()> {
    // xtask lives at rust/xtask; the lint target is rust/src.
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .context("xtask has no parent dir")?
        .to_path_buf();
    let mut files = Vec::new();
    collect_rs(&crate_root.join("src"), &mut files)?;
    files.sort();

    let mut findings = Vec::new();
    for path in &files {
        let source = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let rel = path
            .strip_prefix(&crate_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(lint_source(&rel, &source)?);
    }

    if findings.is_empty() {
        println!("xtask lint: clean ({} files)", files.len());
        return Ok(());
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    for f in &findings {
        eprintln!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    bail!("xtask lint: {} finding(s)", findings.len());
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("read_dir {}", dir.display()))? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file's source. `rel` is the crate-relative path
/// (`src/...`), which the allowlists match on. Public for the tests.
fn lint_source(rel: &str, source: &str) -> Result<Vec<Finding>> {
    let ast = syn::parse_file(source).with_context(|| format!("parse {rel}"))?;
    let mut linter = Linter {
        rel,
        lines: source.lines().collect(),
        relaxed_file_ok: RELAXED_ALLOWLIST.iter().any(|(f, _)| rel.ends_with(f)),
        hot_path: HOT_PATH_DIRS.iter().any(|d| rel.contains(d)),
        migrated: MIGRATED_MODULES.iter().any(|m| rel.ends_with(m)),
        findings: Vec::new(),
    };
    linter.visit_file(&ast);
    Ok(linter.findings)
}

struct Linter<'a> {
    rel: &'a str,
    lines: Vec<&'a str>,
    relaxed_file_ok: bool,
    hot_path: bool,
    migrated: bool,
    findings: Vec<Finding>,
}

impl Linter<'_> {
    fn push(&mut self, line: usize, rule: &'static str, msg: String) {
        self.findings.push(Finding { file: self.rel.to_string(), line, rule, msg });
    }

    /// Is `marker` on the span's own line, or in the contiguous run of
    /// comment / attribute / blank lines directly above it?
    fn has_marker(&self, line: usize, marker: &str) -> bool {
        if line == 0 || line > self.lines.len() {
            return false;
        }
        if self.lines[line - 1].contains(marker) {
            return true;
        }
        let mut idx = line - 1; // 1-based line above the span
        let mut walked = 0;
        while idx >= 1 && walked < MARKER_LOOKBACK {
            let text = self.lines[idx - 1].trim_start();
            if text.starts_with("//") {
                if text.contains(marker) {
                    return true;
                }
            } else if !(text.is_empty() || text.starts_with("#[") || text.starts_with("#!")) {
                break; // hit real code: the comment run ended
            }
            idx -= 1;
            walked += 1;
        }
        false
    }
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        a.path().is_ident("cfg")
            && a.meta
                .require_list()
                .map(|l| l.tokens.to_string() == "test")
                .unwrap_or(false)
    })
}

/// Flatten a use tree into full segment paths (groups fan out).
fn flatten_use(tree: &syn::UseTree, prefix: &mut Vec<String>, out: &mut Vec<Vec<String>>) {
    match tree {
        syn::UseTree::Path(p) => {
            prefix.push(p.ident.to_string());
            flatten_use(&p.tree, prefix, out);
            prefix.pop();
        }
        syn::UseTree::Name(n) => {
            let mut path = prefix.clone();
            path.push(n.ident.to_string());
            out.push(path);
        }
        syn::UseTree::Rename(r) => {
            let mut path = prefix.clone();
            path.push(r.ident.to_string());
            out.push(path);
        }
        syn::UseTree::Glob(_) => {
            let mut path = prefix.clone();
            path.push("*".to_string());
            out.push(path);
        }
        syn::UseTree::Group(g) => {
            for t in &g.items {
                flatten_use(t, prefix, out);
            }
        }
    }
}

impl<'ast> Visit<'ast> for Linter<'_> {
    fn visit_item_mod(&mut self, m: &'ast syn::ItemMod) {
        if is_cfg_test(&m.attrs) {
            return; // test code: panics and SeqCst experiments are fine
        }
        visit::visit_item_mod(self, m);
    }

    fn visit_expr_unsafe(&mut self, e: &'ast syn::ExprUnsafe) {
        let line = e.unsafe_token.span.start().line;
        if !self.has_marker(line, "SAFETY:") {
            self.push(
                line,
                "safety-comment",
                "unsafe block without a `// SAFETY:` comment stating why it is sound".into(),
            );
        }
        visit::visit_expr_unsafe(self, e);
    }

    fn visit_item_impl(&mut self, i: &'ast syn::ItemImpl) {
        if let Some(tok) = &i.unsafety {
            let line = tok.span.start().line;
            if !self.has_marker(line, "SAFETY:") {
                self.push(
                    line,
                    "safety-comment",
                    "unsafe impl without a `// SAFETY:` comment".into(),
                );
            }
        }
        visit::visit_item_impl(self, i);
    }

    fn visit_expr_path(&mut self, p: &'ast syn::ExprPath) {
        let segs: Vec<String> = p.path.segments.iter().map(|s| s.ident.to_string()).collect();
        if segs.len() >= 2 && segs[segs.len() - 2] == "Ordering" {
            let variant = segs[segs.len() - 1].as_str();
            let line = p.path.segments.last().unwrap().ident.span().start().line;
            match variant {
                "SeqCst" => {
                    if !self.has_marker(line, "ordering:") {
                        self.push(
                            line,
                            "atomic-ordering",
                            "Ordering::SeqCst is banned: name the acquire/release edge \
                             instead, or justify with an `// ordering:` comment"
                                .into(),
                        );
                    }
                }
                "Relaxed" => {
                    if !self.relaxed_file_ok && !self.has_marker(line, "ordering:") {
                        self.push(
                            line,
                            "atomic-ordering",
                            "Ordering::Relaxed outside the allowlist: add the file with a \
                             justification in xtask, or an `// ordering:` comment at the site"
                                .into(),
                        );
                    }
                }
                _ => {}
            }
        }
        visit::visit_expr_path(self, p);
    }

    fn visit_expr_method_call(&mut self, c: &'ast syn::ExprMethodCall) {
        if self.hot_path {
            let name = c.method.to_string();
            if name == "unwrap" || name == "expect" {
                let line = c.method.span().start().line;
                if !self.has_marker(line, "lint:allow(unwrap-expect)") {
                    self.push(
                        line,
                        "hot-path-unwrap",
                        format!(
                            ".{name}() on a serving path: recover or propagate instead, or \
                             waive with `// lint:allow(unwrap-expect): <why>`"
                        ),
                    );
                }
            }
        }
        visit::visit_expr_method_call(self, c);
    }

    fn visit_item_use(&mut self, u: &'ast syn::ItemUse) {
        if self.migrated {
            let mut paths = Vec::new();
            flatten_use(&u.tree, &mut Vec::new(), &mut paths);
            for path in paths {
                if path.len() >= 3
                    && path[0] == "std"
                    && path[1] == "sync"
                    && !ALLOWED_STD_SYNC.contains(&path[2].as_str())
                {
                    let line = u.use_token.span.start().line;
                    self.push(
                        line,
                        "std-sync-import",
                        format!(
                            "`use {}` in a loom-shim-migrated module: import it from \
                             `crate::util::sync` so the models keep covering it",
                            path.join("::")
                        ),
                    );
                }
            }
        }
        visit::visit_item_use(self, u);
    }
}

#[cfg(test)]
mod tests {
    use super::lint_source;

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        lint_source(rel, src).unwrap().iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_block_requires_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }";
        assert_eq!(rules("src/x.rs", bad), vec!["safety-comment"]);
        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller promised p is valid.\n    unsafe { *p }\n}";
        assert!(rules("src/x.rs", good).is_empty());
    }

    #[test]
    fn marker_sees_through_attributes_and_comment_runs() {
        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller promised p is\n    // valid for reads.\n    #[allow(clippy::let_and_return)]\n    let v = unsafe { *p };\n    v\n}";
        assert!(rules("src/x.rs", good).is_empty());
    }

    #[test]
    fn seqcst_is_banned_and_relaxed_needs_allowlist_or_comment() {
        let seqcst = "fn f(a: &std::sync::atomic::AtomicUsize) { a.store(0, std::sync::atomic::Ordering::SeqCst); }";
        assert_eq!(rules("src/x.rs", seqcst), vec!["atomic-ordering"]);
        let relaxed = "fn f(a: &std::sync::atomic::AtomicUsize) { a.store(0, std::sync::atomic::Ordering::Relaxed); }";
        assert_eq!(rules("src/x.rs", relaxed), vec!["atomic-ordering"]);
        // Allowlisted file: relaxed is fine.
        assert!(rules("src/metrics/registry.rs", relaxed).is_empty());
        // Site comment: also fine.
        let commented = "fn f(a: &std::sync::atomic::AtomicUsize) {\n    // ordering: Relaxed — monotonic counter.\n    a.store(0, std::sync::atomic::Ordering::Relaxed);\n}";
        assert!(rules("src/x.rs", commented).is_empty());
        // `cmp::Ordering` variants never trip the rule.
        let cmp = "fn f() -> std::cmp::Ordering { std::cmp::Ordering::Less }";
        assert!(rules("src/x.rs", cmp).is_empty());
    }

    #[test]
    fn hot_path_unwrap_flagged_only_in_hot_dirs_and_waivable() {
        let bad = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        assert_eq!(rules("src/server/h.rs", bad), vec!["hot-path-unwrap"]);
        assert_eq!(rules("src/coordinator/h.rs", bad), vec!["hot-path-unwrap"]);
        assert!(rules("src/util/h.rs", bad).is_empty());
        let waived = "fn f(x: Option<u8>) -> u8 {\n    // lint:allow(unwrap-expect): startup only.\n    x.unwrap()\n}";
        assert!(rules("src/server/h.rs", waived).is_empty());
        // unwrap_or_else is not unwrap.
        let recover = "fn f(x: Option<u8>) -> u8 { x.unwrap_or_else(|| 0) }";
        assert!(rules("src/server/h.rs", recover).is_empty());
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Option::<u8>::None.unwrap(); }\n}";
        assert!(rules("src/server/h.rs", src).is_empty());
    }

    #[test]
    fn migrated_modules_reject_wrapped_std_sync_imports() {
        let banned = "use std::sync::Mutex;";
        assert_eq!(rules("src/coordinator/cache.rs", banned), vec!["std-sync-import"]);
        let grouped = "use std::sync::{Arc, atomic::AtomicU64};";
        assert_eq!(rules("src/devices/executor.rs", grouped), vec!["std-sync-import"]);
        let fine = "use std::sync::{Arc, mpsc, PoisonError};";
        assert!(rules("src/coordinator/queue_manager.rs", fine).is_empty());
        // Non-migrated files may import std::sync directly.
        assert!(rules("src/coordinator/batcher.rs", banned).is_empty());
    }

    /// The metrics subsystem is loom-modeled (the trace-ring seqlock and
    /// histogram cells), so the whole module family is migrated: raw
    /// `std::sync` atomics there would silently escape the models.
    #[test]
    fn metrics_modules_are_migrated() {
        let banned = "use std::sync::atomic::AtomicU64;";
        for file in [
            "src/metrics/trace.rs",
            "src/metrics/histogram.rs",
            "src/metrics/registry.rs",
            "src/metrics/slo.rs",
        ] {
            assert_eq!(rules(file, banned), vec!["std-sync-import"], "{file}");
        }
    }
}
