//! END-TO-END DRIVER: the full WindVE system on a real workload.
//!
//! 1. Calibrate this host's real PJRT engine (§4.2.2: fit t = α·C + β,
//!    solve queue depths for a host-scaled SLO).
//! 2. Start the WindVE service — queue manager + two real engine
//!    instances ("NPU" role and "CPU" offload role, each its own model
//!    copy) — and drive closed-loop concurrent clients through it.
//! 3. Compare against the non-offloading baseline (CPU queue disabled,
//!    what FlagEmbedding gives you) at the same concurrency: report
//!    throughput, p50/p99 latency, SLO attainment and busy rejects.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use windve::coordinator::instance::BackendFactory;
use windve::coordinator::{ServiceConfig, WindVE};
use windve::coordinator::service::ServeError;
use windve::devices::executor::RealBackend;
use windve::metrics::Histogram;
use windve::repro::calibrate::calibrate_host;
use windve::workload::queries::QueryGen;

struct PhaseResult {
    name: String,
    served: u64,
    busy: u64,
    timeouts: u64,
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    slo_attainment: f64,
    npu_share: f64,
}

fn real_factory(artifacts: std::path::PathBuf, model: String) -> BackendFactory {
    Box::new(move || {
        Ok(Box::new(RealBackend::load(&artifacts, &model)?)
            as Box<dyn windve::devices::executor::Backend>)
    })
}

/// Closed-loop phase: `clients` threads, each embeds sequentially for
/// `duration`.
fn run_phase(
    name: &str,
    svc: &Arc<WindVE>,
    clients: usize,
    duration: Duration,
    slo: Duration,
    qlen: usize,
) -> PhaseResult {
    let hist = Arc::new(Histogram::new());
    let served = Arc::new(AtomicU64::new(0));
    let busy = Arc::new(AtomicU64::new(0));
    let violations = Arc::new(AtomicU64::new(0));
    let timeouts = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicU64::new(0));

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|cid| {
            let svc = Arc::clone(svc);
            let hist = Arc::clone(&hist);
            let served = Arc::clone(&served);
            let busy = Arc::clone(&busy);
            let violations = Arc::clone(&violations);
            let timeouts = Arc::clone(&timeouts);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut gen = QueryGen::new(qlen, 0x9A55 + cid as u64);
                while stop.load(Ordering::Relaxed) == 0 {
                    let q = gen.query();
                    let t = Instant::now();
                    match svc.embed_blocking(q, slo.mul_f64(40.0)) {
                        Ok(_) => {
                            let el = t.elapsed();
                            hist.record(el.as_nanos() as u64);
                            served.fetch_add(1, Ordering::Relaxed);
                            if el > slo {
                                violations.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(ServeError::Busy) => {
                            busy.fetch_add(1, Ordering::Relaxed);
                            // paper client: back off briefly on 'busy'
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(ServeError::Timeout) => {
                            // Count as a (gross) SLO violation; the slot is
                            // still released by the worker when the batch
                            // completes.
                            timeouts.fetch_add(1, Ordering::Relaxed);
                            violations.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("serve error: {e}"),
                    }
                }
            })
        })
        .collect();
    std::thread::sleep(duration);
    stop.store(1, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc.queue_manager().stats();
    let (rn, rc) = (stats.routed_npu, stats.routed_cpu);
    let served_n = served.load(Ordering::Relaxed);
    PhaseResult {
        name: name.to_string(),
        served: served_n,
        busy: busy.load(Ordering::Relaxed),
        timeouts: timeouts.load(Ordering::Relaxed),
        qps: served_n as f64 / wall,
        p50_ms: hist.p50() as f64 / 1e6,
        p99_ms: hist.p99() as f64 / 1e6,
        slo_attainment: {
            let v = violations.load(Ordering::Relaxed);
            let total = served_n + timeouts.load(Ordering::Relaxed);
            if total == 0 { 1.0 } else { 1.0 - v as f64 / total as f64 }
        },
        npu_share: if rn + rc == 0 { 1.0 } else { rn as f64 / (rn + rc) as f64 },
    }
}

fn print_result(r: &PhaseResult, slo: Duration) {
    println!(
        "  {:<26} served {:>5} ({:>6.1} q/s)  p50 {:>7.1} ms  p99 {:>7.1} ms  SLO({}ms) {:>5.1}%  busy {:>4}  timeouts {:>3}  npu-share {:>4.0}%",
        r.name, r.served, r.qps, r.p50_ms, r.p99_ms,
        slo.as_millis(), 100.0 * r.slo_attainment, r.busy, r.timeouts, 100.0 * r.npu_share
    );
}

/// Block until the service's backends are compiled and serving (engine
/// warmup happens on the worker threads; measuring it would charge AOT
/// compile time to the serving phase).
fn wait_ready(svc: &Arc<WindVE>, probes: usize) {
    let t0 = Instant::now();
    for i in 0..probes.max(1) {
        let _ = svc.embed_blocking(format!("warmup probe {i}"), Duration::from_secs(300));
    }
    println!("  (service ready in {:?})", t0.elapsed());
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("WINDVE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let qlen = 75; // the paper's canonical RAG segment length
    println!("== phase 0: host calibration (paper §4.2.2 on the real engine) ==");
    let cal = calibrate_host(&artifacts, "bge_micro", qlen, 1.0, 3)?;
    windve::repro::calibrate::print(&cal);

    // Host-scaled SLO: tight enough that the queue-depth decision matters
    // on this machine — 4x the fitted batch-8 latency.
    let slo_s = (cal.fit.predict(8.0) * 4.0).clamp(0.05, 2.0);
    let slo = Duration::from_secs_f64(slo_s);
    let npu_depth = cal.fit.max_concurrency(slo_s).clamp(1, 16);
    let cpu_depth = (npu_depth / 3).max(2);
    println!(
        "\nhost-scaled SLO {:.0} ms → NPU-role depth {npu_depth}, CPU-role depth {cpu_depth}",
        slo_s * 1e3
    );

    let peak_clients = npu_depth + cpu_depth; // paper: peak = joint capacity
    let phase_len = Duration::from_secs(10);

    println!("\n== phase 1: WindVE (hetero offloading ON) ==");
    let windve_svc = Arc::new(WindVE::start(
        ServiceConfig {
            npu_depth,
            cpu_depth,
            hetero: true,
            npu_workers: 1,
            cpu_workers: 1,
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
            ..ServiceConfig::default()
        },
        vec![real_factory(artifacts.clone(), "bge_micro".into())],
        vec![real_factory(artifacts.clone(), "bge_micro".into())],
    )?);
    wait_ready(&windve_svc, peak_clients);
    let windve_res = run_phase("WindVE (offloading)", &windve_svc, peak_clients, phase_len, slo, qlen);
    print_result(&windve_res, slo);
    drop(windve_svc);

    println!("\n== phase 2: baseline (no offloading — FlagEmbedding-style) ==");
    let base_svc = Arc::new(WindVE::start(
        ServiceConfig {
            npu_depth,
            cpu_depth: 0,
            hetero: false,
            npu_workers: 1,
            cpu_workers: 0,
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
            ..ServiceConfig::default()
        },
        vec![real_factory(artifacts.clone(), "bge_micro".into())],
        vec![],
    )?);
    wait_ready(&base_svc, peak_clients);
    let base_res = run_phase("baseline (NPU only)", &base_svc, peak_clients, phase_len, slo, qlen);
    print_result(&base_res, slo);
    drop(base_svc);

    println!("\n== summary ==");
    print_result(&base_res, slo);
    print_result(&windve_res, slo);
    let uplift = 100.0 * (windve_res.qps / base_res.qps - 1.0);
    println!(
        "\nWindVE serves {:.1}% more throughput at peak concurrency {} \
         (busy rejects: baseline {}, WindVE {})",
        uplift, peak_clients, base_res.busy, windve_res.busy
    );
    anyhow::ensure!(
        windve_res.busy < base_res.busy || windve_res.qps > base_res.qps,
        "offloading should reduce rejects or raise throughput"
    );
    println!("peak_offload E2E OK");
    Ok(())
}
