//! Deployment-cost planner: the paper's §3 analysis end to end.
//!
//! Takes the diurnal day curve (Fig. 2), provisions an embedding fleet
//! three ways — average-rate (Eq. 5), peak NPU-only (Eq. 6), peak with
//! WindVE CPU offloading — and replays the day through the open-loop
//! simulator to show what each choice does to SLO attainment and rejects.

use windve::costmodel::{self, CostInputs};
use windve::devices::profile::DeviceProfile;
use windve::sim::des::OpenLoopSim;
use windve::workload::diurnal::DiurnalCurve;

fn main() {
    let slo = 1.0;
    let npu = DeviceProfile::v100_bge();
    let cpu = DeviceProfile::xeon_e5_2690_bge();
    let c_npu = npu.true_max_concurrency(slo, 75);
    let c_cpu = cpu.true_max_concurrency(slo, 75);

    // A consumer app's day, scaled so the evening peak needs ~3 instances.
    let curve = DiurnalCurve::typical(20.0, 10.0);
    let mean = curve.mean_rate();
    let peak = curve.peak_rate();
    println!("day curve: mean {mean:.1} q/s, peak {peak:.1} q/s (peak/mean {:.2}x)", peak / mean);

    let inp = CostInputs { devices_per_instance: 1.0, price_per_device: 10_000.0 };
    // Throughput of one instance ≈ C / t(C) at the SLO point.
    let t_at_c = npu.service_time(c_npu, 75);
    let inst_qps = c_npu as f64 / t_at_c;
    let n_slots = costmodel::waiting_slots(slo, t_at_c / c_npu as f64);

    let cost_avg = costmodel::cost_average(mean, n_slots, inst_qps, inp);
    let cost_peak_npu = costmodel::cost_peak(peak, c_npu as f64, inp);
    let cost_peak_windve = costmodel::cost_peak(peak, (c_npu + c_cpu) as f64, inp);
    println!("\nprovisioning costs (Eq. 5 / Eq. 6, arbitrary $ scale):");
    println!("  average-rate (Eq. 5):        ${cost_avg:>10.0}");
    println!("  peak NPU-only (Eq. 6):       ${cost_peak_npu:>10.0}");
    println!("  peak WindVE (NPU+CPU):       ${cost_peak_windve:>10.0}");
    println!(
        "  WindVE saves {:.1}% of peak provisioning (paper bound C_CPU/(C_CPU+C_NPU) = {:.1}%)",
        100.0 * (1.0 - cost_peak_windve / cost_peak_npu),
        100.0 * costmodel::savings_peak(c_npu, c_cpu),
    );

    // Replay the evening peak hour through the open-loop simulator with
    // an average-provisioned single instance, with and without offload.
    println!("\nreplaying the 20:30 peak hour (one instance):");
    let peak_rate = curve.rate(20.5);
    let arrivals = OpenLoopSim::poisson_arrivals(|_| peak_rate, peak_rate, 120.0, 7);
    for (name, cpu_prof, cpu_depth) in [
        ("NPU only (baseline)", None, 0usize),
        ("WindVE (CPU offload)", Some(cpu.clone()), c_cpu),
    ] {
        let sim = OpenLoopSim {
            npu: npu.clone(),
            cpu: cpu_prof,
            npu_depth: c_npu,
            cpu_depth,
            qlen: 75,
            slo,
            seed: 11,
        };
        let st = sim.run(&arrivals);
        println!(
            "  {:<22} arrived {:>5}  served {:>5}  rejected {:>4} ({:>4.1}%)  SLO attainment {:>5.1}%  p99 {:>6.0} ms",
            name,
            st.arrived,
            st.served(),
            st.rejected,
            100.0 * st.reject_rate(),
            100.0 * st.slo_attainment(),
            st.latency_us.p99() as f64 / 1e3,
        );
    }
    println!("\ncost_planner OK");
}
