//! Quickstart: load the AOT-compiled embedding model and embed a few
//! queries — the minimal "is everything wired" example.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use windve::runtime::{engine::cosine, EmbeddingEngine};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("WINDVE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    println!("loading bge_micro from {} ...", artifacts.display());
    let mut engine = EmbeddingEngine::load(&artifacts, "bge_micro")?;
    println!(
        "loaded in {:?} (d_model={}, max batch={})",
        engine.load_time,
        engine.d_model(),
        engine.max_batch()
    );

    let texts = vec![
        "retrieval augmented generation for large language models".to_string(),
        "rag systems ground llm answers in retrieved documents".to_string(),
        "the evening traffic peak overwhelms the embedding service".to_string(),
    ];
    let t0 = std::time::Instant::now();
    let vecs = engine.embed(&texts)?;
    println!("embedded {} texts in {:?}", texts.len(), t0.elapsed());

    for (t, v) in texts.iter().zip(&vecs) {
        let head: Vec<String> = v.iter().take(5).map(|x| format!("{x:+.3}")).collect();
        println!("  {:<60} -> [{} ...]", format!("{t:?}"), head.join(" "));
    }
    println!("\npairwise cosine similarities:");
    for i in 0..vecs.len() {
        for j in (i + 1)..vecs.len() {
            println!("  ({i}, {j}): {:+.4}", cosine(&vecs[i], &vecs[j]));
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
