//! RAG retrieval example: embed a document corpus once, then serve
//! retrieval queries against it — the workload the paper's introduction
//! motivates (vector embedding inside a RAG stack).
//!
//! Uses the real PJRT engine end to end: corpus embedding is batched
//! through the same buckets the serving path uses, and retrieval runs on
//! the vecstore's SIMD-dispatched batched scan ([`Index::search_batch`])
//! — all queries share one sharded top-k pass instead of scanning the
//! corpus once per query.

use windve::runtime::EmbeddingEngine;
use windve::vecstore::{kernels, FlatIndex, Index};

const CORPUS: &[&str] = &[
    "WindVE offloads peak embedding queries from the NPU to host CPUs",
    "the queue manager gives strict priority to the accelerator queue",
    "a linear regression estimator calibrates queue depths against the SLO",
    "retrieval augmented generation fuses retrieved passages into prompts",
    "vector embeddings map sentences into a unit hypersphere",
    "cosine similarity over unit vectors reduces to a dot product",
    "the device detector decides main and auxiliary processing roles",
    "CPU affinity should be assigned in reversed core order on ARM hosts",
    "crossing NUMA nodes degrades memory bandwidth for embedding workers",
    "deployment cost scales inversely with maximum concurrency",
    "diurnal traffic peaks at dinner time for consumer applications",
    "stress testing with large increments risks missing the optimal depth",
    "bge large zh produces one thousand twenty four dimensional vectors",
    "jina embeddings support eight thousand token documents",
    "the busy status tells clients to back off when both queues fill",
    "flash attention streams key value blocks through on-chip memory",
    "the feed forward network dominates encoder inference flops",
    "mean pooling with a padding mask ignores phantom tokens",
    "model weights stay resident on device across requests",
    "static shape buckets trade padding waste for compile-once execution",
];

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("WINDVE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let mut engine = EmbeddingEngine::load(&artifacts, "bge_micro")?;

    // Index the corpus (one batched pass; engine chunks to its buckets).
    let docs: Vec<String> = CORPUS.iter().map(|s| s.to_string()).collect();
    let t0 = std::time::Instant::now();
    let embedded = engine.embed(&docs)?;
    let mut index = FlatIndex::new(embedded[0].len());
    for (i, dv) in embedded.iter().enumerate() {
        index.add(i as u64, dv);
    }
    println!(
        "indexed {} documents in {:?} ({:.1} docs/s, scan kernel: {})",
        docs.len(),
        t0.elapsed(),
        docs.len() as f64 / t0.elapsed().as_secs_f64(),
        kernels::name()
    );

    let queries = [
        "how does windve handle traffic peaks",
        "how are queue depths chosen",
        "numa and core pinning advice",
        "what does mean pooling do with padding",
    ];
    // Embed the whole query panel in one engine batch, then answer every
    // query with a single batched top-k scan.
    let texts: Vec<String> = queries.iter().map(|q| q.to_string()).collect();
    let qvecs = engine.embed(&texts)?;
    let qrefs: Vec<&[f32]> = qvecs.iter().map(|v| v.as_slice()).collect();
    let t1 = std::time::Instant::now();
    let results = index.search_batch(&qrefs, 3);
    println!("batched retrieval of {} queries in {:?}", queries.len(), t1.elapsed());
    for (q, hits) in queries.iter().zip(&results) {
        println!("\nquery: {q:?}");
        for h in hits {
            println!("  {:+.4}  {}", h.score, CORPUS[h.id as usize]);
        }
    }
    println!("\nrag_pipeline OK");
    Ok(())
}
